//! **Projected** H100 (Hopper) device — a forward-looking extension.
//!
//! The paper's Table 1 lists Hopper's preliminary features ("Hopper GPUs
//! are not publicly released yet"): FP8 joins the menu, INT4/Binary are
//! dropped, sparsity and the mma/ldmatrix interface carry over. This
//! configuration projects the paper's methodology onto that device:
//! peaks follow the H100 whitepaper (~2x A100 per SM at iso-clock
//! accounting), latencies carry over from Ampere (the paper observed
//! completion latency did not improve Turing -> Ampere).
//!
//! It is *not* part of the paper's evaluation, but it is registered in
//! `device::registry()` (as `hopper-projected`) so `/v1/devices`,
//! `repro sweep --device hopper-projected` and `Workload::validate` can
//! target it — notably for the fp8 numeric probes, which only this
//! device's FP8 Tensor Cores admit. INT4/Binary workloads are rejected
//! here (dropped on Hopper, Table 1).

use crate::isa::shapes::*;
use crate::isa::{AbType, CdType, MmaInstr};

use super::config::{Arch, Device, FpuFallback, MmaTiming, PeakTable};

fn t(latency: u32, ii: u32) -> MmaTiming {
    MmaTiming { latency, ii, fpu_fallback: FpuFallback::No }
}

/// Build the projected Hopper device.
pub fn hopper_projected() -> Device {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};

    // Peaks: 2x A100 dense per SM (989 TFLOPS FP16 dense / 132 SM / 1.98
    // GHz ≈ 1890 FMA/clk/SM -> 2048 nominal).
    let dense: Vec<(MmaInstr, MmaTiming)> = vec![
        (MmaInstr::dense(Fp16, C32, M16N8K16), t(24, 4)),
        (MmaInstr::dense(Fp16, C32, M16N8K8), t(17, 2)),
        (MmaInstr::dense(Fp16, C16, M16N8K16), t(23, 4)),
        (MmaInstr::dense(Fp16, C16, M16N8K8), t(17, 2)),
        (MmaInstr::dense(Bf16, C32, M16N8K16), t(24, 4)),
        (MmaInstr::dense(Bf16, C32, M16N8K8), t(17, 2)),
        (MmaInstr::dense(Tf32, C32, M16N8K8), t(24, 4)),
        (MmaInstr::dense(Tf32, C32, M16N8K4), t(17, 2)),
        (MmaInstr::dense(Int8, I32, M16N8K32), t(24, 4)),
        (MmaInstr::dense(Int8, I32, M16N8K16), t(17, 2)),
    ];
    let sparse: Vec<(MmaInstr, MmaTiming)> = vec![
        (MmaInstr::sp(Fp16, C32, M16N8K32), t(24, 4)),
        (MmaInstr::sp(Fp16, C32, M16N8K16), t(17, 2)),
        (MmaInstr::sp(Bf16, C32, M16N8K32), t(24, 4)),
        (MmaInstr::sp(Bf16, C32, M16N8K16), t(17, 2)),
        (MmaInstr::sp(Tf32, C32, M16N8K16), t(24, 4)),
        (MmaInstr::sp(Int8, I32, M16N8K64), t(24, 4)),
    ];
    let paper_dense_rows = dense.iter().map(|(i, _)| *i).collect();
    let paper_sparse_rows = sparse.iter().map(|(i, _)| *i).collect();
    let mut mma_timings = dense;
    mma_timings.extend(sparse);

    Device {
        name: "hopper-projected",
        product: "NVIDIA H100 (projected — not measured by the paper)",
        arch: Arch::Ampere, // same SM organization: 4 sub-cores, 4 TCs
        sms: 132,
        subcores: 4,
        lsu_units: 2,
        lsu_txn_cycles: 2,
        lsu_tail: 21,
        lsu_pending_per_warp: 4,
        smem_banks: 32,
        smem_bank_bytes: 4,
        smem_bytes_per_sm: 228 * 1024, // GH100: up to 228 KB/SM
        sync_cost: 1,
        gmem_latency: 400,
        gmem_bytes_per_cycle: 12,
        peaks: PeakTable {
            fp16_fp32: 2048,
            fp16_fp16: 2048,
            bf16: 2048,
            tf32: 1024,
            int8: 4096,
            int4: 0,   // dropped on Hopper (Table 1)
            binary: 0, // dropped on Hopper
            fp8: 4096, // new on Hopper (Table 11): 2x the FP16 rate
        },
        mma_timings,
        paper_dense_rows,
        paper_sparse_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::microbench::measure_mma;

    #[test]
    fn projected_peaks_double_a100() {
        let h = hopper_projected();
        let a = crate::device::a100();
        assert_eq!(h.peaks.fp16_fp32, 2 * a.peaks.fp16_fp32);
        assert_eq!(h.peaks.int4, 0, "INT4 dropped on Hopper (Table 1)");
    }

    #[test]
    fn projected_throughput_reaches_2x() {
        let h = hopper_projected();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        let m = measure_mma(&h, &i, 8, 4);
        assert!(m.throughput > 1900.0, "{m:?}");
    }

    #[test]
    fn latency_carries_over_from_ampere() {
        // the paper: completion latency did not improve Turing->Ampere;
        // we project the same for Hopper.
        let h = hopper_projected();
        let a = crate::device::a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        assert_eq!(h.timing(&i).unwrap().latency, a.timing(&i).unwrap().latency);
    }

    #[test]
    fn registered_with_fp8_but_without_int4() {
        // the satellite registry contract: addressable by name, fp8
        // allowed, INT4/Binary rejected (dropped on Hopper, Table 1)
        let h = crate::device::by_name("hopper-projected").expect("registered");
        assert!(h.supports_fp8());
        assert!(!crate::device::a100().supports_fp8());
        let int4 = MmaInstr::dense(AbType::Int4, CdType::Int32, M16N8K32);
        assert!(!h.supports(&int4), "INT4 must be rejected on Hopper");
        let binary = MmaInstr::dense(AbType::Binary, CdType::Int32, M16N8K128);
        assert!(!h.supports(&binary), "Binary must be rejected on Hopper");
        // fp8 numeric probes validate here and nowhere else
        let probe = crate::workload::Workload::parse_spec("numeric profile fp8e5m2 f32 mul").unwrap();
        assert!(probe.validate(&h).is_ok());
        assert!(probe.validate(&crate::device::a100()).is_err());
    }
}
