//! NVIDIA A100 (GA100, Ampere) calibration — paper Tables 3 and 6.
//!
//! Completion latencies are taken from the paper's measured "Completion
//! Latency" columns (`pipeline depth = measured - sync_cost`); initiation
//! intervals follow `ii = FMAs ÷ (peak/4 sub-cores)` with two documented
//! anomalies:
//!
//! * `INT8 m8n8k16` runs at ii=4 (ideal 2): "m8n8k16 is an old shape
//!   optimized for Turing Tensor Cores" and only reaches ~half peak.
//! * every `mma.sp` *small-k* shape runs at ii=6 (ideal 4): the Fig. 11
//!   finding that A100 sparse small-k "can not achieve peak throughput"
//!   ("the vendor does not document the reason").

use crate::isa::shapes::*;
use crate::isa::{AbType, CdType, MmaInstr};

use super::config::{Arch, Device, FpuFallback, MmaTiming, PeakTable};

fn t(latency: u32, ii: u32) -> MmaTiming {
    MmaTiming { latency, ii, fpu_fallback: FpuFallback::No }
}

/// Build the calibrated A100 device.
pub fn a100() -> Device {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};

    // ------------------------------------------------------- dense mma
    let dense: Vec<(MmaInstr, MmaTiming)> = vec![
        // Table 3 rows (completion latency - 1, ii from 1024/512/2048/
        // 4096/16384 FMA/clk/SM peaks).
        (MmaInstr::dense(Fp16, C32, M16N8K16), t(24, 8)),
        (MmaInstr::dense(Fp16, C32, M16N8K8), t(17, 4)),
        (MmaInstr::dense(Fp16, C16, M16N8K16), t(23, 8)),
        (MmaInstr::dense(Fp16, C16, M16N8K8), t(17, 4)),
        (MmaInstr::dense(Tf32, C32, M16N8K8), t(24, 8)),
        (MmaInstr::dense(Tf32, C32, M16N8K4), t(17, 4)),
        (MmaInstr::dense(Int8, I32, M8N8K16), t(15, 4)), // anomaly: ideal ii 2
        (MmaInstr::dense(Int8, I32, M16N8K32), t(24, 8)),
        (MmaInstr::dense(Int8, I32, M16N8K16), t(17, 4)),
        (MmaInstr::dense(Int4, I32, M16N8K32), t(17, 4)),
        (MmaInstr::dense(Int4, I32, M16N8K64), t(25, 8)),
        (MmaInstr::dense(Binary, I32, M16N8K128), t(17, 4)),
        (MmaInstr::dense(Binary, I32, M16N8K256), t(25, 8)),
        // BF16 — identical timing to FP16 (paper conclusion; Fig. 6/7
        // were measured with BF16).
        (MmaInstr::dense(Bf16, C32, M16N8K16), t(24, 8)),
        (MmaInstr::dense(Bf16, C32, M16N8K8), t(17, 4)),
        // mma.m8n8k4 FP16: compiled to FPU code on Ampere, ~10x slower
        // than the Tensor-Core expectation (§2.2). 256 FMA at ~26 FMA/clk
        // per sub-core.
        (
            MmaInstr::dense(Fp16, C32, M8N8K4),
            MmaTiming { latency: 30, ii: 10, fpu_fallback: FpuFallback::Yes },
        ),
    ];

    // ------------------------------------------------------ sparse mma
    let sparse: Vec<(MmaInstr, MmaTiming)> = vec![
        // Table 6 rows. Large-k: same latency/ii as the dense half-k
        // counterpart (the dense path goes through the sparse selector
        // too — §6 finding 1). Small-k: ii=6 anomaly.
        (MmaInstr::sp(Fp16, C32, M16N8K32), t(24, 8)),
        (MmaInstr::sp(Fp16, C32, M16N8K16), t(17, 6)),
        (MmaInstr::sp(Fp16, C16, M16N8K32), t(23, 8)),
        (MmaInstr::sp(Fp16, C16, M16N8K16), t(17, 6)),
        (MmaInstr::sp(Tf32, C32, M16N8K16), t(24, 8)),
        (MmaInstr::sp(Tf32, C32, M16N8K8), t(17, 6)),
        (MmaInstr::sp(Int8, I32, M16N8K64), t(24, 8)),
        (MmaInstr::sp(Int8, I32, M16N8K32), t(17, 6)),
        // BF16 sparse for the Fig. 10/11 sweeps.
        (MmaInstr::sp(Bf16, C32, M16N8K32), t(24, 8)),
        (MmaInstr::sp(Bf16, C32, M16N8K16), t(17, 6)),
    ];

    let paper_dense_rows = dense[..13].iter().map(|(i, _)| *i).collect();
    let paper_sparse_rows = sparse[..8].iter().map(|(i, _)| *i).collect();

    let mut mma_timings = dense;
    mma_timings.extend(sparse);

    Device {
        name: "a100",
        product: "NVIDIA A100 (GA100)",
        arch: Arch::Ampere,
        sms: 108,
        subcores: 4,
        lsu_units: 2,
        lsu_txn_cycles: 2,
        lsu_tail: 21,
        lsu_pending_per_warp: 4,
        smem_banks: 32,
        smem_bank_bytes: 4,
        smem_bytes_per_sm: 164 * 1024, // GA100: up to 164 KB/SM
        sync_cost: 1,
        gmem_latency: 400,
        // ~10 B/clk/SM of DRAM bandwidth (1555 GB/s / 108 SMs / 1.41GHz);
        // 8 keeps the Appendix-A staging model integral.
        gmem_bytes_per_cycle: 8,
        peaks: PeakTable {
            fp16_fp32: 1024,
            fp16_fp16: 1024,
            bf16: 1024,
            tf32: 512,
            int8: 2048,
            int4: 4096,
            binary: 16384,
            fp8: 0, // no FP8 before Hopper (Table 11)
        },
        mma_timings,
        paper_dense_rows,
        paper_sparse_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_ii_matches_peak_except_documented_anomalies() {
        let d = a100();
        for (instr, timing) in &d.mma_timings {
            if timing.fpu_fallback == FpuFallback::Yes {
                continue;
            }
            let ideal = d.ideal_ii(instr);
            let anomaly_int8_m8n8k16 =
                !instr.sparse && instr.ab == AbType::Int8 && instr.shape == M8N8K16;
            let anomaly_sparse_small_k = instr.sparse && timing.ii == 6;
            if anomaly_int8_m8n8k16 {
                assert_eq!(timing.ii, 2 * ideal, "{instr}");
            } else if anomaly_sparse_small_k {
                assert_eq!(ideal, 4, "{instr}");
            } else {
                assert_eq!(timing.ii, ideal, "{instr}");
            }
        }
    }

    #[test]
    fn sparse_latency_matches_dense_counterpart() {
        // §6 finding 1: mma.sp.m16n8k32 has the same completion latency
        // as dense mma.m16n8k16 — the selector is in the pipeline for
        // both.
        let d = a100();
        let sp = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
        let dn = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
        assert_eq!(d.timing(&sp).unwrap().latency, d.timing(&dn).unwrap().latency);
    }

    #[test]
    fn m8n8k4_is_fpu_fallback() {
        let d = a100();
        let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M8N8K4);
        assert_eq!(d.timing(&i).unwrap().fpu_fallback, FpuFallback::Yes);
    }

    #[test]
    fn bf16_matches_fp16_timing() {
        let d = a100();
        let bf = d.timing(&MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16)).unwrap();
        let fp = d.timing(&MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16)).unwrap();
        assert_eq!(bf.latency, fp.latency);
        assert_eq!(bf.ii, fp.ii);
    }
}
