//! Calibrated device descriptions.
//!
//! Each [`Device`] carries the *structural* parameters of one GPU's SM
//! (sub-core count, LSU count, shared-memory banks, …) and a calibrated
//! per-instruction pipeline table (completion latency + initiation
//! interval). Completion latencies are the quantity the paper measured
//! (its Tables 3–7); initiation intervals follow from the vendor peak
//! throughput (`ii = FMAs/instr ÷ peak-FMA/clk/sub-core`) except for the
//! documented anomalies (DESIGN.md §4):
//!
//! * A100 `mma.sp` small-k shapes run at ii≈6 instead of the ideal
//!   (the paper's "can not reach the theoretical peak" finding, Fig. 11);
//! * A100 INT8 `m8n8k16` runs at half rate ("old shape optimized for
//!   Turing Tensor Cores");
//! * RTX3070Ti halves the FP16 rate when the accumulator is FP32
//!   (the GA102 gaming-die rule, Table 4);
//! * Ampere `mma.m8n8k4` FP16 compiles to FPU code ~10x slower (§2.2).

mod a100;
mod config;
mod hopper;
mod rtx2080ti;
mod rtx3070ti;

pub use a100::a100;
pub use config::{Arch, Device, FpuFallback, MmaTiming, PeakTable};
pub use hopper::hopper_projected;
pub use rtx2080ti::rtx2080ti;
pub use rtx3070ti::rtx3070ti;

use crate::isa::MmaInstr;

/// All addressable devices, by CLI name: the paper's three measured
/// GPUs plus the projected Hopper target (fp8-capable, INT4/Binary
/// dropped — see [`hopper_projected`]).
pub fn registry() -> Vec<Device> {
    vec![a100(), rtx3070ti(), rtx2080ti(), hopper_projected()]
}

/// Look up a device by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Device> {
    let lower = name.to_ascii_lowercase();
    registry().into_iter().find(|d| d.name.to_ascii_lowercase() == lower)
}

/// The dense instruction rows of the paper's Table 3/4/5 for a device.
pub fn dense_table_rows(device: &Device) -> Vec<MmaInstr> {
    device.paper_dense_rows.clone()
}

/// The sparse instruction rows of the paper's Table 6/7 for a device.
pub fn sparse_table_rows(device: &Device) -> Vec<MmaInstr> {
    device.paper_sparse_rows.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_paper_devices_plus_hopper() {
        let names: Vec<_> = registry().into_iter().map(|d| d.name).collect();
        assert_eq!(names, vec!["a100", "rtx3070ti", "rtx2080ti", "hopper-projected"]);
        // fp8 capability is exactly the Hopper column of Table 11
        for d in registry() {
            assert_eq!(d.supports_fp8(), d.name == "hopper-projected", "{}", d.name);
        }
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(by_name("A100").is_some());
        assert!(by_name("RTX3070Ti").is_some());
        assert!(by_name("Hopper-Projected").is_some());
        assert!(by_name("h100").is_none());
    }

    #[test]
    fn table_row_counts_match_paper() {
        assert_eq!(dense_table_rows(&a100()).len(), 13); // Table 3
        assert_eq!(sparse_table_rows(&a100()).len(), 8); // Table 6
        assert_eq!(dense_table_rows(&rtx3070ti()).len(), 13); // Table 4
        assert_eq!(sparse_table_rows(&rtx3070ti()).len(), 8); // Table 7
        assert_eq!(dense_table_rows(&rtx2080ti()).len(), 3); // Table 5
        assert_eq!(sparse_table_rows(&rtx2080ti()).len(), 0); // no mma.sp on Turing
    }
}
