//! Chrome trace-event export of a Tracing-mode [`SimProfile`].
//!
//! A profile collected with
//! [`ProfileMode::Tracing`](crate::sim::ProfileMode) carries the
//! per-warp issue timeline; this module renders it in the Chrome
//! trace-event JSON format (`{"traceEvents": [...]}` with `ph: "X"`
//! complete events), which `chrome://tracing` and
//! <https://ui.perfetto.dev> open directly. One warp maps to one
//! track (`tid`), named via `thread_name` metadata events; timestamps
//! and durations are simulated *cycles*, displayed by the viewers in
//! their microsecond unit (1 cycle renders as 1 µs — relative layout,
//! not wall time).

use crate::sim::SimProfile;
use crate::util::Json;

/// Render `profile.events` as a Chrome trace-event JSON document.
/// Counting-mode profiles (no timeline) yield an empty-but-valid trace.
pub fn trace_to_json(profile: &SimProfile) -> Json {
    let warps = profile.events.iter().map(|e| e.warp + 1).max().unwrap_or(0);
    let mut events: Vec<Json> = Vec::with_capacity(profile.events.len() + warps);
    for warp in 0..warps {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(warp as f64)),
            ("args", Json::obj(vec![("name", Json::Str(format!("warp {warp}")))])),
        ]));
    }
    for e in &profile.events {
        events.push(Json::obj(vec![
            ("name", Json::str(e.name)),
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(e.warp as f64)),
            ("ts", Json::num(e.ts as f64)),
            ("dur", Json::num(e.dur.max(1) as f64)),
        ]));
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::a100;
    use crate::isa::{AbType, CdType, MmaInstr, MmaShape};
    use crate::microbench::measure_mma_profiled;
    use crate::sim::Profiler;

    #[test]
    fn counting_profiles_export_an_empty_valid_trace() {
        let j = trace_to_json(&SimProfile::default());
        assert!(j.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn traced_run_exports_named_warp_tracks() {
        let d = a100();
        let instr = MmaInstr::dense(AbType::Bf16, CdType::Fp32, MmaShape::new(16, 8, 16));
        let mut profiler = Profiler::tracing();
        measure_mma_profiled(&d, &instr, 2, 2, &mut profiler);
        let p = profiler.take_profile().unwrap();
        assert!(!p.events.is_empty());

        let j = trace_to_json(&p);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 warps → 2 thread_name metadata events, then the timeline
        let meta: Vec<_> =
            events.iter().filter(|e| e.get_str("ph") == Some("M")).collect();
        assert_eq!(meta.len(), 2);
        assert_eq!(
            meta[0].get("args").unwrap().get_str("name"),
            Some("warp 0")
        );
        let complete: Vec<_> =
            events.iter().filter(|e| e.get_str("ph") == Some("X")).collect();
        assert_eq!(complete.len(), p.events.len());
        for e in &complete {
            assert!(e.get_f64("ts").is_some() && e.get_f64("dur").unwrap() >= 1.0, "{e}");
        }
        assert!(complete.iter().any(|e| e.get_str("name") == Some("mma")));
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
