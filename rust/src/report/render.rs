//! Plain-text table / figure rendering (no external crates), including
//! the uniform renderers over the workload layer's sweeps
//! ([`render_sweep_figure`]) and plan results ([`render_bench`]).

use crate::microbench::Sweep;
use crate::workload::{BenchResult, NumericOutput, UnitOutput};

/// A simple column-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Render a figure as CSV: one row per x-value, one column per series.
pub fn render_figure_csv(
    x_label: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
) -> String {
    let mut out = String::new();
    out.push_str(x_label);
    for (name, _) in series {
        out.push(',');
        out.push_str(name);
    }
    out.push('\n');
    for (i, x) in xs.iter().enumerate() {
        out.push_str(&format!("{x}"));
        for (_, ys) in series {
            out.push(',');
            if let Some(y) = ys.get(i) {
                if y.is_finite() {
                    out.push_str(&format!("{y:.4}"));
                } else {
                    out.push_str("inf");
                }
            }
        }
        out.push('\n');
    }
    out
}

/// Render a Fig. 6/7/10/11/15-style grid: latency and throughput versus
/// ILP, one sparkline series per #warps, plus the embedded CSV block
/// `report::json` parses back out.
pub fn render_sweep_figure(title: &str, sweep: &Sweep) -> String {
    let xs: Vec<f64> = sweep.ilp_axis.iter().map(|&i| i as f64).collect();
    let mut out = format!("## {title}\n\n");
    for metric in ["throughput", "latency"] {
        let series: Vec<(String, Vec<f64>)> = sweep
            .warps_axis
            .iter()
            .map(|&w| {
                let ys: Vec<f64> = sweep
                    .ilp_axis
                    .iter()
                    .map(|&ilp| {
                        let c = sweep.cell(w, ilp).expect("full sweep grid");
                        if metric == "throughput" {
                            c.throughput
                        } else {
                            c.latency
                        }
                    })
                    .collect();
                (format!("{w}w"), ys)
            })
            .collect();
        out.push_str(&format!("### {metric} vs ILP\n"));
        for (name, ys) in &series {
            out.push_str(&format!(
                "{name:>4} {}  {}\n",
                render_sparkline(ys),
                ys.iter().map(|y| format!("{y:.0}")).collect::<Vec<_>>().join(" ")
            ));
        }
        let named: Vec<(&str, Vec<f64>)> =
            series.iter().map(|(n, y)| (n.as_str(), y.clone())).collect();
        out.push_str("\ncsv:\n");
        out.push_str(&render_figure_csv("ilp", &xs, &named));
        out.push('\n');
    }
    out
}

/// Render a workload plan result: a summary table over the completion /
/// point / convergence units, followed by the sweep figure when the
/// plan requested one. The text twin of
/// [`bench_to_json`](crate::report::bench_to_json).
pub fn render_bench(r: &BenchResult) -> String {
    let mut out = format!(
        "## {} on {} [{}] — {} runner\n\n",
        r.workload, r.device_name, r.arch, r.runner
    );
    let thr_hdr = format!("thr ({})", r.throughput_unit);
    let mut t = Table::new("", &["unit", "warps", "ILP", "latency (cy)", thr_hdr.as_str()]);
    let mut rows = 0usize;
    let mut numeric_lines = String::new();
    for (_, output) in &r.units {
        match output {
            UnitOutput::Completion(latency) => {
                t.row(vec![
                    "completion".into(),
                    "1".into(),
                    "1".into(),
                    format!("{latency:.1}"),
                    "-".into(),
                ]);
                rows += 1;
            }
            UnitOutput::Point(m) => {
                t.row(vec![
                    "point".into(),
                    m.warps.to_string(),
                    m.ilp.to_string(),
                    format!("{:.1}", m.latency),
                    format!("{:.1}", m.throughput),
                ]);
                rows += 1;
            }
            UnitOutput::Sweep { convergence, .. } => {
                for c in convergence {
                    t.row(vec![
                        "convergence".into(),
                        c.warps.to_string(),
                        c.ilp.to_string(),
                        format!("{:.1}", c.latency),
                        format!("{:.1}", c.throughput),
                    ]);
                    rows += 1;
                }
            }
            UnitOutput::Numeric(NumericOutput::Profile(p)) => {
                numeric_lines.push_str(&format!(
                    "numeric profile: {} / init_{}: mean |err| = {:.2e} \
                     (vs CPU_FP32cvtFP16: {:.2e}, {} trials)\n",
                    p.op.paper_name(),
                    p.init.spec_name(),
                    p.mean_abs_err,
                    p.mean_abs_err_vs_cvt_fp16,
                    p.trials
                ));
            }
            UnitOutput::Numeric(NumericOutput::Chain(c)) => {
                numeric_lines.push_str(&format!(
                    "numeric chain (N = {}): {}  err(1) = {:.1e}",
                    c.rel_err.len(),
                    render_sparkline(&c.rel_err),
                    c.rel_err.first().copied().unwrap_or(f64::NAN),
                ));
                match c.overflow_at {
                    Some(at) => numeric_lines
                        .push_str(&format!("  — overflow (inf) at N = {at}\n")),
                    None => numeric_lines.push_str(&format!(
                        "  err(end) = {:.1e}\n",
                        c.rel_err.last().copied().unwrap_or(f64::NAN)
                    )),
                }
            }
        }
    }
    if rows > 0 {
        out.push_str(&t.render());
        out.push('\n');
    }
    if !numeric_lines.is_empty() {
        out.push_str(&numeric_lines);
        out.push('\n');
    }
    for (_, output) in &r.units {
        if let UnitOutput::Sweep { sweep, .. } = output {
            out.push_str(&render_sweep_figure(
                &format!("{} sweep on {}", r.workload, r.device_name),
                sweep,
            ));
        }
    }
    out
}

/// Unicode sparkline of a series (terminal "figure").
pub fn render_sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return String::new();
    }
    let (lo, hi) = finite
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    values
        .iter()
        .map(|&v| {
            if !v.is_finite() {
                return '∞';
            }
            if hi == lo {
                return BARS[0];
            }
            let t = ((v - lo) / (hi - lo) * 7.0).round() as usize;
            BARS[t.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "long header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yy".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().collect();
        // title + header + sep + 2 rows
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("t", &["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn bench_result_renders_table_and_sweep() {
        use crate::workload::{Plan, SimRunner, Workload};
        let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
        let plan = Plan::new(w)
            .completion_latency()
            .point(8, 2)
            .sweep()
            .compile()
            .unwrap();
        let r = plan.run(&SimRunner, 1).unwrap();
        let text = render_bench(&r);
        assert!(text.contains("a100"), "{text}");
        assert!(text.contains("completion"));
        assert!(text.contains("convergence"));
        assert!(text.contains("csv:"));
        // the summary table parses back out through report::json
        let tables = crate::report::json::parse_tables(&text);
        assert!(!tables.is_empty());
    }

    #[test]
    fn csv_and_sparkline() {
        let csv = render_figure_csv("N", &[1.0, 2.0], &[("tf32", vec![0.1, 0.2])]);
        assert!(csv.starts_with("N,tf32\n1,0.1000\n"));
        let sl = render_sparkline(&[0.0, 0.5, 1.0, f64::INFINITY]);
        assert_eq!(sl.chars().count(), 4);
        assert!(sl.ends_with('∞'));
    }
}
