//! Plain-text table / figure rendering (no external crates).

/// A simple column-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Render a figure as CSV: one row per x-value, one column per series.
pub fn render_figure_csv(
    x_label: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
) -> String {
    let mut out = String::new();
    out.push_str(x_label);
    for (name, _) in series {
        out.push(',');
        out.push_str(name);
    }
    out.push('\n');
    for (i, x) in xs.iter().enumerate() {
        out.push_str(&format!("{x}"));
        for (_, ys) in series {
            out.push(',');
            if let Some(y) = ys.get(i) {
                if y.is_finite() {
                    out.push_str(&format!("{y:.4}"));
                } else {
                    out.push_str("inf");
                }
            }
        }
        out.push('\n');
    }
    out
}

/// Unicode sparkline of a series (terminal "figure").
pub fn render_sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return String::new();
    }
    let (lo, hi) = finite
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    values
        .iter()
        .map(|&v| {
            if !v.is_finite() {
                return '∞';
            }
            if hi == lo {
                return BARS[0];
            }
            let t = ((v - lo) / (hi - lo) * 7.0).round() as usize;
            BARS[t.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "long header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yy".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().collect();
        // title + header + sep + 2 rows
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("t", &["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn csv_and_sparkline() {
        let csv = render_figure_csv("N", &[1.0, 2.0], &[("tf32", vec![0.1, 0.2])]);
        assert!(csv.starts_with("N,tf32\n1,0.1000\n"));
        let sl = render_sparkline(&[0.0, 0.5, 1.0, f64::INFINITY]);
        assert_eq!(sl.chars().count(), 4);
        assert!(sl.ends_with('∞'));
    }
}
