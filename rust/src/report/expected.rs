//! The paper's published numbers, embedded for side-by-side comparison
//! in every regenerated table (EXPERIMENTS.md is generated from these).

use crate::isa::{shapes::*, AbType, CdType, LdMatrixNum, MmaInstr};

/// One row of a Table 3/4/5/6/7-style instruction table.
#[derive(Debug, Clone, Copy)]
pub struct PaperMmaRow {
    pub instr: MmaInstr,
    pub completion: f64,
    pub p4: (u32, f64, f64), // (ILP, latency, throughput) at 4 warps
    pub p8: (u32, f64, f64), // at 8 warps
}

fn row(
    instr: MmaInstr,
    completion: f64,
    p4: (u32, f64, f64),
    p8: (u32, f64, f64),
) -> PaperMmaRow {
    PaperMmaRow { instr, completion, p4, p8 }
}

/// Table 3: dense mma on A100.
pub fn table3() -> Vec<PaperMmaRow> {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};
    vec![
        row(MmaInstr::dense(Fp16, C32, M16N8K16), 24.7, (3, 27.4, 897.6), (2, 32.6, 1004.2)),
        row(MmaInstr::dense(Fp16, C32, M16N8K8), 17.7, (4, 20.5, 800.2), (3, 25.3, 974.1)),
        row(MmaInstr::dense(Fp16, C16, M16N8K16), 24.4, (3, 27.1, 907.1), (2, 32.9, 996.6)),
        row(MmaInstr::dense(Fp16, C16, M16N8K8), 17.7, (4, 19.1, 860.9), (3, 24.5, 1002.6)),
        row(MmaInstr::dense(Tf32, C32, M16N8K8), 25.0, (3, 28.2, 435.9), (2, 33.3, 492.4)),
        row(MmaInstr::dense(Tf32, C32, M16N8K4), 18.1, (4, 20.9, 392.6), (3, 25.7, 477.5)),
        row(MmaInstr::dense(Int8, I32, M8N8K16), 15.9, (4, 20.1, 813.2), (2, 16.4, 998.3)),
        row(MmaInstr::dense(Int8, I32, M16N8K32), 24.7, (3, 27.1, 1812.4), (2, 32.9, 1986.5)),
        row(MmaInstr::dense(Int8, I32, M16N8K16), 17.6, (4, 20.9, 1570.1), (3, 25.1, 1965.1)),
        row(MmaInstr::dense(Int4, I32, M16N8K32), 18.1, (4, 22.1, 2971.1), (3, 27.1, 3630.0)),
        row(MmaInstr::dense(Int4, I32, M16N8K64), 26.1, (3, 28.1, 3497.9), (2, 35.8, 3660.8)),
        row(MmaInstr::dense(Binary, I32, M16N8K128), 18.1, (4, 22.1, 11884.3), (3, 27.1, 14515.1)),
        row(MmaInstr::dense(Binary, I32, M16N8K256), 26.0, (3, 28.1, 13985.4), (2, 35.8, 14643.4)),
    ]
}

/// Table 4: dense mma on RTX3070Ti.
pub fn table4() -> Vec<PaperMmaRow> {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};
    vec![
        row(MmaInstr::dense(Fp16, C32, M16N8K16), 33.0, (1, 33.0, 248.2), (1, 64.8, 252.7)),
        row(MmaInstr::dense(Fp16, C32, M16N8K8), 18.8, (2, 32.3, 253.9), (1, 32.4, 253.2)),
        row(MmaInstr::dense(Fp16, C16, M16N8K16), 24.0, (2, 32.2, 509.4), (1, 32.3, 506.9)),
        row(MmaInstr::dense(Fp16, C16, M16N8K8), 17.7, (3, 24.0, 511.8), (2, 32.3, 507.8)),
        row(MmaInstr::dense(Tf32, C32, M16N8K8), 33.3, (1, 33.4, 122.6), (1, 64.6, 126.8)),
        row(MmaInstr::dense(Tf32, C32, M16N8K4), 19.1, (2, 32.7, 125.3), (1, 32.6, 125.7)),
        row(MmaInstr::dense(Int8, I32, M8N8K16), 15.9, (4, 19.3, 848.9), (2, 16.2, 1008.5)),
        row(MmaInstr::dense(Int8, I32, M16N8K32), 24.3, (2, 32.2, 1017.2), (1, 32.1, 1023.2)),
        row(MmaInstr::dense(Int8, I32, M16N8K16), 17.7, (3, 24.1, 1018.2), (2, 32.6, 1005.4)),
        row(MmaInstr::dense(Int4, I32, M16N8K32), 17.3, (3, 24.9, 1967.9), (2, 32.3, 2031.7)),
        row(MmaInstr::dense(Int4, I32, M16N8K64), 24.5, (2, 33.3, 1967.9), (1, 32.5, 2013.5)),
        row(MmaInstr::dense(Binary, I32, M16N8K128), 17.3, (3, 24.8, 7908.3), (2, 32.3, 8127.2)),
        row(MmaInstr::dense(Binary, I32, M16N8K256), 24.6, (2, 33.3, 7871.9), (1, 32.5, 8053.9)),
    ]
}

/// Table 5: dense mma on RTX2080Ti (Turing).
pub fn table5() -> Vec<PaperMmaRow> {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};
    vec![
        row(MmaInstr::dense(Fp16, C32, M16N8K8), 17.3, (2, 32.5, 252.4), (1, 32.1, 255.1)),
        row(MmaInstr::dense(Fp16, C16, M16N8K8), 14.7, (2, 17.5, 467.9), (1, 16.1, 509.4)),
        row(MmaInstr::dense(Int8, I32, M8N8K16), 11.0, (3, 14.5, 846.1), (2, 16.2, 1012.6)),
    ]
}

/// Table 6: sparse mma on A100.
pub fn table6() -> Vec<PaperMmaRow> {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};
    vec![
        row(MmaInstr::sp(Fp16, C32, M16N8K32), 24.7, (3, 27.4, 1791.9), (2, 33.1, 1979.1)),
        row(MmaInstr::sp(Fp16, C32, M16N8K16), 17.8, (3, 20.4, 1024.5), (2, 25.4, 1290.5)),
        row(MmaInstr::sp(Fp16, C16, M16N8K32), 24.3, (3, 26.6, 1850.9), (2, 32.4, 2019.8)),
        row(MmaInstr::sp(Fp16, C16, M16N8K16), 17.6, (3, 19.8, 1242.9), (2, 24.9, 1318.2)),
        row(MmaInstr::sp(Tf32, C32, M16N8K16), 24.9, (3, 28.3, 868.2), (2, 33.9, 981.2)),
        row(MmaInstr::sp(Tf32, C32, M16N8K8), 18.2, (3, 20.6, 597.8), (2, 25.5, 643.6)),
        row(MmaInstr::sp(Int8, I32, M16N8K64), 24.7, (3, 27.7, 3544.7), (2, 33.1, 3961.5)),
        row(MmaInstr::sp(Int8, I32, M16N8K32), 17.9, (3, 20.4, 2403.9), (2, 25.4, 2665.2)),
    ]
}

/// Table 7: sparse mma on RTX3070Ti.
pub fn table7() -> Vec<PaperMmaRow> {
    use AbType::*;
    use CdType::{Fp16 as C16, Fp32 as C32, Int32 as I32};
    vec![
        row(MmaInstr::sp(Fp16, C32, M16N8K32), 33.0, (1, 33.0, 496.5), (1, 64.1, 511.2)),
        row(MmaInstr::sp(Fp16, C32, M16N8K16), 18.8, (2, 32.3, 507.8), (1, 32.4, 506.2)),
        row(MmaInstr::sp(Fp16, C16, M16N8K32), 24.3, (2, 32.0, 1022.2), (1, 32.1, 1022.3)),
        row(MmaInstr::sp(Fp16, C16, M16N8K16), 17.7, (3, 24.2, 1013.4), (2, 32.0, 1023.1)),
        row(MmaInstr::sp(Tf32, C32, M16N8K16), 33.2, (1, 33.2, 247.0), (1, 64.2, 255.1)),
        row(MmaInstr::sp(Tf32, C32, M16N8K8), 19.0, (2, 32.5, 252.5), (1, 32.4, 253.2)),
        // NB: the paper prints (4,2) latency 64.2 with throughput 2040.2
        // for INT8 m16n8k64 — internally inconsistent (thr*lat != W*ILP*
        // FMA); we carry the throughput and the consistent latency 32.1.
        row(MmaInstr::sp(Int8, I32, M16N8K64), 24.3, (2, 32.1, 2040.2), (1, 32.1, 2039.5)),
        row(MmaInstr::sp(Int8, I32, M16N8K32), 17.7, (3, 24.2, 2028.8), (2, 32.3, 2031.8)),
    ]
}

/// One row of Table 9 (ldmatrix on A100).
#[derive(Debug, Clone, Copy)]
pub struct PaperLdmatrixRow {
    pub num: LdMatrixNum,
    pub bytes_per_warp: u64,
    pub completion: f64,
    pub p4: (u32, f64, f64),
    pub p8: (u32, f64, f64),
}

/// Table 9: ldmatrix performance on A100.
pub fn table9() -> Vec<PaperLdmatrixRow> {
    vec![
        PaperLdmatrixRow {
            num: LdMatrixNum::X1,
            bytes_per_warp: 128,
            completion: 23.1,
            p4: (5, 26.8, 95.4),
            p8: (4, 32.1, 127.7),
        },
        PaperLdmatrixRow {
            num: LdMatrixNum::X2,
            bytes_per_warp: 256,
            completion: 25.1,
            p4: (4, 32.1, 127.8),
            p8: (2, 32.1, 127.7),
        },
        PaperLdmatrixRow {
            num: LdMatrixNum::X4,
            bytes_per_warp: 512,
            completion: 29.3,
            p4: (2, 32.2, 127.3),
            p8: (1, 32.6, 125.9),
        },
    ]
}

/// Table 10: ld.shared latency (cycles) under bank conflicts.
/// (width, ways, latency); u64 has no conflict-free configuration.
pub fn table10() -> Vec<(&'static str, u32, f64)> {
    vec![
        ("u32", 1, 23.0),
        ("u32", 2, 25.0),
        ("u32", 4, 29.0),
        ("u32", 8, 37.0),
        ("u64", 2, 25.1),
        ("u64", 4, 29.1),
        ("u64", 8, 37.0),
    ]
}

/// Tables 12/13/15: mean |error| of (multiplication, inner product,
/// accumulation) per (config, init strategy).
pub struct PaperNumericRow {
    pub table: &'static str,
    pub cfg: &'static str,
    pub init: &'static str,
    pub mul: f64,
    pub inner: f64,
    pub accum: f64,
}

pub fn numeric_tables() -> Vec<PaperNumericRow> {
    vec![
        PaperNumericRow { table: "12", cfg: "bf16_f32", init: "low", mul: 0.0, inner: 0.0, accum: 1.89e-8 },
        PaperNumericRow { table: "12", cfg: "bf16_f32", init: "fp32", mul: 1.29e-3, inner: 1.72e-3, accum: 1.13e-3 },
        PaperNumericRow { table: "13", cfg: "fp16_f32", init: "low", mul: 0.0, inner: 0.0, accum: 0.0 },
        PaperNumericRow { table: "13", cfg: "fp16_f32", init: "fp32", mul: 1.59e-4, inner: 2.18e-4, accum: 1.36e-4 },
        PaperNumericRow { table: "14", cfg: "fp16_f16", init: "low", mul: 1.22e-4, inner: 1.81e-4, accum: 1.81e-4 },
        PaperNumericRow { table: "15", cfg: "tf32_f32", init: "low", mul: 0.0, inner: 0.0, accum: 0.0 },
        PaperNumericRow { table: "15", cfg: "tf32_f32", init: "fp32", mul: 1.59e-4, inner: 2.17e-4, accum: 1.36e-4 },
    ]
}

/// Tables 16/17: Appendix-A GPU cycle counts.
pub const TABLE16_BASELINE: u64 = 913_363;
pub const TABLE16_PIPELINE: u64 = 451_560;
pub const TABLE17_PERMUTED: u64 = 303_227;

/// Figure 17: FP16 chains overflow at N >= 10.
pub const FIG17_FP16_OVERFLOW_N: usize = 10;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_counts_match_paper() {
        assert_eq!(table3().len(), 13);
        assert_eq!(table4().len(), 13);
        assert_eq!(table5().len(), 3);
        assert_eq!(table6().len(), 8);
        assert_eq!(table7().len(), 8);
        assert_eq!(table9().len(), 3);
        assert_eq!(table10().len(), 7);
    }

    #[test]
    fn paper_rows_are_internally_consistent() {
        // thr ≈ warps*ILP*FMA / latency for every published point
        // (±6%, reflecting the paper's own measurement noise).
        for (rows, warps4, warps8) in [(table3(), 4.0, 8.0), (table6(), 4.0, 8.0)] {
            for r in rows {
                let f = r.instr.fmas() as f64;
                let t4 = warps4 * r.p4.0 as f64 * f / r.p4.1;
                let t8 = warps8 * r.p8.0 as f64 * f / r.p8.1;
                // Known exception: Table 6's mma.sp FP16/FP32 m16n8k16
                // (4,3) point prints 1024.5 where W*ILP*FMA/lat = 1204.7
                // — the paper's own cell is inconsistent (cf. the Table 7
                // INT8 m16n8k64 latency typo).
                let tol4 = if r.instr.sparse && r.instr.shape.k == 16 && r.instr.cd == CdType::Fp32
                {
                    0.20
                } else {
                    0.06
                };
                assert!((t4 / r.p4.2 - 1.0).abs() < tol4, "{}: {t4} vs {}", r.instr, r.p4.2);
                assert!((t8 / r.p8.2 - 1.0).abs() < 0.06, "{}: {t8} vs {}", r.instr, r.p8.2);
            }
        }
    }

    #[test]
    fn expected_rows_supported_by_devices() {
        let a100 = crate::device::a100();
        for r in table3().iter().chain(table6().iter()) {
            assert!(a100.supports(&r.instr), "{}", r.instr);
        }
        let ga104 = crate::device::rtx3070ti();
        for r in table4().iter().chain(table7().iter()) {
            assert!(ga104.supports(&r.instr), "{}", r.instr);
        }
        let tu102 = crate::device::rtx2080ti();
        for r in table5() {
            assert!(tu102.supports(&r.instr), "{}", r.instr);
        }
    }
}
