//! Report rendering: ASCII tables in the paper's layout, figure series
//! (CSV + sparkline), the paper's published values for side-by-side
//! comparison in every regenerated table, and a machine-readable JSON
//! rendering of every report ([`json`]).

pub mod expected;
pub mod json;
mod render;

pub use json::{deviation_stats, report_to_json, DeviationStats};
pub use render::{render_figure_csv, render_sparkline, Table};

/// Relative deviation string for paper-vs-measured columns.
pub fn deviation(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        return "-".to_string();
    }
    let pct = (measured - paper) / paper * 100.0;
    format!("{pct:+.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_formatting() {
        assert_eq!(deviation(110.0, 100.0), "+10.0%");
        assert_eq!(deviation(97.0, 100.0), "-3.0%");
        assert_eq!(deviation(1.0, 0.0), "-");
    }
}
