//! Report rendering: ASCII tables in the paper's layout, figure series
//! (CSV + sparkline), the paper's published values for side-by-side
//! comparison in every regenerated table, a machine-readable JSON
//! rendering of every report ([`json`]), and the uniform text/JSON
//! renderers over the workload layer's [`BenchResult`]
//! ([`render_bench`] / [`bench_to_json`]).
//!
//! [`BenchResult`]: crate::workload::BenchResult

pub mod expected;
pub mod json;
mod render;
pub mod trace;

pub use json::{
    bench_to_json, deviation_stats, diagnostic_to_json, lint_records_to_json, lint_to_json,
    report_to_json, sim_profile_to_json, sweep_to_json, unit_output_to_json, DeviationStats,
};
pub use render::{
    render_bench, render_figure_csv, render_sparkline, render_sweep_figure, Table,
};
pub use trace::trace_to_json;

/// Relative deviation string for paper-vs-measured columns.
pub fn deviation(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        return "-".to_string();
    }
    let pct = (measured - paper) / paper * 100.0;
    format!("{pct:+.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_formatting() {
        assert_eq!(deviation(110.0, 100.0), "+10.0%");
        assert_eq!(deviation(97.0, 100.0), "-3.0%");
        assert_eq!(deviation(1.0, 0.0), "-");
    }
}
