//! Machine-readable rendering of experiment reports.
//!
//! The experiment functions return the paper-styled plain-text reports
//! (ASCII tables, sparklines, embedded CSV). This module lifts that
//! exact format — which [`crate::report::render`] fully controls — into
//! structured [`Json`]: tables become `{title, headers, rows}`, the
//! `csv:` figure blocks become `{columns, rows}`, and the `dev` columns
//! are summarized into paper-vs-simulator deviation statistics. The
//! tcserved `/v1/run` endpoint and `repro all --out DIR`'s
//! `summary.json` are both built on this path.

use crate::analysis::Diagnostic;
use crate::microbench::{ConvergencePoint, Sweep};
use crate::sim::SimProfile;
use crate::util::Json;
use crate::workload::{BenchResult, LintRecord, NumericOutput, UnitOutput};

/// Is this line a table separator (`----+-----+----`)?
fn is_separator(line: &str) -> bool {
    !line.is_empty() && line.chars().all(|c| c == '-' || c == '+') && line.contains('-')
}

fn split_cells(line: &str) -> Vec<String> {
    line.split('|').map(|c| c.trim().to_string()).collect()
}

/// Extract every ASCII table of a rendered report as
/// `{title, headers, rows}` objects (rows are arrays of cell strings).
pub fn parse_tables(text: &str) -> Vec<Json> {
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::new();
    let mut last_title = "";
    let mut i = 0;
    while i < lines.len() {
        if let Some(t) = lines[i].strip_prefix("## ") {
            last_title = t.trim();
            i += 1;
            continue;
        }
        if is_separator(lines[i]) && i > 0 && lines[i - 1].contains('|') {
            let headers: Vec<Json> =
                split_cells(lines[i - 1]).into_iter().map(Json::Str).collect();
            let mut rows = Vec::new();
            let mut j = i + 1;
            while j < lines.len() && lines[j].contains('|') && !is_separator(lines[j]) {
                rows.push(Json::Arr(split_cells(lines[j]).into_iter().map(Json::Str).collect()));
                j += 1;
            }
            out.push(Json::obj(vec![
                ("title", Json::str(last_title)),
                ("headers", Json::Arr(headers)),
                ("rows", Json::Arr(rows)),
            ]));
            i = j;
            continue;
        }
        i += 1;
    }
    out
}

fn csv_cell(s: &str) -> Json {
    let s = s.trim();
    if s.is_empty() {
        return Json::Null;
    }
    match s.parse::<f64>() {
        // keep "inf" (what render_figure_csv emits for overflow) as a
        // string: bare infinity is not valid JSON
        Ok(v) if v.is_finite() => Json::Num(v),
        _ => Json::str(s),
    }
}

/// Extract every `csv:` figure block as `{columns, rows}` (numeric cells
/// parsed, empty cells `null`, non-finite kept as strings).
pub fn parse_csv_blocks(text: &str) -> Vec<Json> {
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        if lines[i].trim() != "csv:" {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if j < lines.len() && lines[j].contains(',') {
            let columns: Vec<Json> =
                lines[j].split(',').map(|s| Json::str(s.trim())).collect();
            j += 1;
            let mut rows = Vec::new();
            while j < lines.len() && lines[j].contains(',') {
                rows.push(Json::Arr(lines[j].split(',').map(csv_cell).collect()));
                j += 1;
            }
            out.push(Json::obj(vec![
                ("columns", Json::Arr(columns)),
                ("rows", Json::Arr(rows)),
            ]));
        }
        i = j.max(i + 1);
    }
    out
}

/// Paper-vs-simulator deviation summary of one report, aggregated over
/// every `±x.y%` cell its tables contain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviationStats {
    /// Number of deviation cells found.
    pub cells: usize,
    pub mean_abs_pct: f64,
    pub max_abs_pct: f64,
}

impl DeviationStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cells", Json::num(self.cells as f64)),
            ("mean_abs_pct", Json::num(self.mean_abs_pct)),
            ("max_abs_pct", Json::num(self.max_abs_pct)),
        ])
    }
}

/// Scan a rendered report for deviation cells (`+1.2%` / `-0.3%`, the
/// format [`super::deviation`] emits) and summarize them. `None` when the
/// report has no deviation column (pure figures, numeric tables).
pub fn deviation_stats(text: &str) -> Option<DeviationStats> {
    let mut devs: Vec<f64> = Vec::new();
    for table in parse_tables(text) {
        let Some(rows) = table.get("rows").and_then(Json::as_arr) else { continue };
        for row in rows {
            let Some(cells) = row.as_arr() else { continue };
            for cell in cells {
                let Some(s) = cell.as_str() else { continue };
                if let Some(stripped) = s.strip_suffix('%') {
                    if let Ok(v) = stripped.trim().trim_start_matches('+').parse::<f64>() {
                        devs.push(v.abs());
                    }
                }
            }
        }
    }
    if devs.is_empty() {
        return None;
    }
    let mean = devs.iter().sum::<f64>() / devs.len() as f64;
    let max = devs.iter().copied().fold(0.0, f64::max);
    Some(DeviationStats { cells: devs.len(), mean_abs_pct: mean, max_abs_pct: max })
}

/// One measured (warps, ILP, latency, throughput) record — the shared
/// field layout of sweep cells, convergence summaries and plan points.
/// Non-finite metrics (an overflowed chain probe's error cells) are
/// encoded as strings to keep the JSON parseable.
fn point_json(warps: u32, ilp: u32, latency: f64, throughput: f64) -> Json {
    Json::obj(vec![
        ("warps", Json::num(warps as f64)),
        ("ilp", Json::num(ilp as f64)),
        ("latency", finite_num(latency)),
        ("throughput", finite_num(throughput)),
    ])
}

/// Machine-readable rendering of one sweep grid plus its convergence
/// summaries — the payload core of `/v1/sweep` and of sweep plan units.
pub fn sweep_to_json(sweep: &Sweep, convergence: &[ConvergencePoint]) -> Json {
    Json::obj(vec![
        (
            "warps_axis",
            Json::Arr(sweep.warps_axis.iter().map(|&w| Json::num(w as f64)).collect()),
        ),
        (
            "ilp_axis",
            Json::Arr(sweep.ilp_axis.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
        (
            "cells",
            Json::Arr(
                sweep
                    .cells
                    .iter()
                    .map(|c| point_json(c.warps, c.ilp, c.latency, c.throughput))
                    .collect(),
            ),
        ),
        (
            "convergence",
            Json::Arr(
                convergence
                    .iter()
                    .map(|c| point_json(c.warps, c.ilp, c.latency, c.throughput))
                    .collect(),
            ),
        ),
        ("peak_throughput", Json::num(sweep.peak_throughput())),
    ])
}

/// A JSON number that stays parseable on non-finite values (bare `inf`
/// / `NaN` are not valid JSON; chain errors overflow by design).
fn finite_num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(format!("{v}"))
    }
}

/// Machine-readable rendering of one executed plan unit.
pub fn unit_output_to_json(output: &UnitOutput) -> Json {
    match output {
        UnitOutput::Completion(latency) => Json::obj(vec![
            ("unit", Json::str("completion")),
            ("warps", Json::num(1.0)),
            ("ilp", Json::num(1.0)),
            ("latency", Json::num(*latency)),
        ]),
        UnitOutput::Point(m) => {
            let Json::Obj(mut fields) = point_json(m.warps, m.ilp, m.latency, m.throughput)
            else {
                unreachable!("point_json returns an object")
            };
            fields.insert("unit".to_string(), Json::str("point"));
            Json::Obj(fields)
        }
        UnitOutput::Sweep { sweep, convergence } => {
            let Json::Obj(mut fields) = sweep_to_json(sweep, convergence) else {
                unreachable!("sweep_to_json returns an object")
            };
            fields.insert("unit".to_string(), Json::str("sweep"));
            Json::Obj(fields)
        }
        UnitOutput::Numeric(NumericOutput::Profile(p)) => Json::obj(vec![
            ("unit", Json::str("numeric")),
            ("probe", Json::str("profile")),
            ("op", Json::str(p.op.spec_name())),
            ("init", Json::str(p.init.spec_name())),
            ("trials", Json::num(p.trials as f64)),
            ("mean_abs_err", finite_num(p.mean_abs_err)),
            ("mean_abs_err_vs_cvt_fp16", finite_num(p.mean_abs_err_vs_cvt_fp16)),
        ]),
        UnitOutput::Numeric(NumericOutput::Chain(c)) => Json::obj(vec![
            ("unit", Json::str("numeric")),
            ("probe", Json::str("chain")),
            ("steps", Json::num(c.rel_err.len() as f64)),
            (
                "rel_err",
                Json::Arr(c.rel_err.iter().map(|&e| finite_num(e)).collect()),
            ),
            (
                "overflow_at",
                match c.overflow_at {
                    Some(n) => Json::num(n as f64),
                    None => Json::Null,
                },
            ),
        ]),
    }
}

/// Machine-readable rendering of one stall-attribution profile: the
/// seven category counters and fractions (in
/// [`STALL_CATEGORIES`](crate::sim::STALL_CATEGORIES) order), the
/// accounting totals, and the trace-event tally.
pub fn sim_profile_to_json(p: &SimProfile) -> Json {
    let counts: Vec<(&str, Json)> =
        p.categories().iter().map(|&(name, n)| (name, Json::num(n as f64))).collect();
    let fracs: Vec<(&str, Json)> =
        p.fractions().iter().map(|&(name, f)| (name, Json::num(f))).collect();
    Json::obj(vec![
        ("runs", Json::num(p.runs as f64)),
        ("warps", Json::num(p.warps as f64)),
        ("cycles", Json::num(p.cycles as f64)),
        ("warp_cycles", Json::num(p.warp_cycles as f64)),
        ("categories", Json::obj(counts)),
        ("fractions", Json::obj(fracs)),
        ("trace_events", Json::num(p.events.len() as f64)),
        ("trace_events_dropped", Json::num(p.events_dropped as f64)),
    ])
}

/// Machine-readable rendering of one tclint diagnostic: the stable
/// rule id, its severity, and the (warp, instruction) anchor.
pub fn diagnostic_to_json(d: &Diagnostic) -> Json {
    Json::obj(vec![
        ("rule", Json::str(d.rule.id())),
        ("severity", Json::str(d.severity.as_str())),
        ("warp", Json::num(d.warp as f64)),
        (
            "instr",
            match d.instr {
                Some(i) => Json::num(i as f64),
                None => Json::Null,
            },
        ),
        ("message", Json::str(&d.message)),
    ])
}

/// Machine-readable rendering of plan-scoped lint records — each
/// diagnostic plus the (workload, device, warps, ilp) coordinates of
/// the program that triggered it. The diagnostics array of
/// `POST /v1/lint` responses and of [`bench_to_json`].
pub fn lint_records_to_json(records: &[LintRecord]) -> Json {
    Json::Arr(
        records
            .iter()
            .map(|r| {
                let Json::Obj(mut fields) = diagnostic_to_json(&r.diagnostic) else {
                    unreachable!("diagnostic_to_json returns an object")
                };
                fields.insert("workload".to_string(), Json::Str(r.spec.clone()));
                fields.insert("device".to_string(), Json::str(r.device));
                fields.insert("warps".to_string(), Json::num(r.warps as f64));
                fields.insert("ilp".to_string(), Json::num(r.ilp as f64));
                Json::Obj(fields)
            })
            .collect(),
    )
}

/// The `repro lint` artifact (`lint.json`): per-scope diagnostics and
/// error/warning totals in a stable schema — uploaded by the CI lint
/// step. A scope is an experiment id (`--all`) or a workload spec.
pub fn lint_to_json(scopes: &[(String, Vec<LintRecord>)]) -> Json {
    let errors = scopes.iter().flat_map(|(_, r)| r).filter(|r| r.is_error()).count();
    let total: usize = scopes.iter().map(|(_, r)| r.len()).sum();
    Json::obj(vec![
        ("schema", Json::str("tcbench/lint/v1")),
        ("version", Json::str(env!("CARGO_PKG_VERSION"))),
        ("errors", Json::num(errors as f64)),
        ("warnings", Json::num((total - errors) as f64)),
        (
            "scopes",
            Json::Arr(
                scopes
                    .iter()
                    .map(|(scope, records)| {
                        Json::obj(vec![
                            ("scope", Json::Str(scope.clone())),
                            ("diagnostics", lint_records_to_json(records)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Full machine-readable rendering of one plan result — the JSON twin
/// of [`render_bench`](crate::report::render_bench), consumed by
/// `POST /v1/plan` responses and `repro` output files. Units executed
/// with profiling on additionally carry a `"profile"` section
/// ([`sim_profile_to_json`]).
pub fn bench_to_json(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("workload", Json::Str(r.workload.to_spec())),
        ("kind", Json::str(r.workload.kind())),
        ("display", Json::Str(r.workload.to_string())),
        (
            "device",
            Json::obj(vec![
                ("name", Json::str(r.device_name)),
                ("arch", Json::Str(r.arch.clone())),
                ("sms", Json::num(r.sms as f64)),
            ]),
        ),
        ("runner", Json::str(r.runner)),
        ("throughput_unit", Json::str(r.throughput_unit)),
        ("wall_ms", Json::num(r.wall_ms)),
        // tclint findings surfaced by Plan::compile (debug builds; the
        // array is present-but-empty on release-mode results)
        ("diagnostics", lint_records_to_json(&r.diagnostics)),
        (
            "units",
            Json::Arr(
                r.units
                    .iter()
                    .enumerate()
                    .map(|(i, (_, out))| {
                        let mut j = unit_output_to_json(out);
                        if let (Some(p), Json::Obj(fields)) = (r.unit_stall_profile(i), &mut j)
                        {
                            fields.insert("profile".to_string(), sim_profile_to_json(p));
                        }
                        j
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Full machine-readable rendering of one experiment report.
pub fn report_to_json(id: &str, description: &str, text: &str) -> Json {
    let title = text
        .lines()
        .find_map(|l| l.strip_prefix("## "))
        .unwrap_or(description)
        .trim();
    let deviation = match deviation_stats(text) {
        Some(d) => d.to_json(),
        None => Json::Null,
    };
    Json::obj(vec![
        ("id", Json::str(id)),
        ("description", Json::str(description)),
        ("title", Json::str(title)),
        ("tables", Json::Arr(parse_tables(text))),
        ("figures", Json::Arr(parse_csv_blocks(text))),
        ("deviation", deviation),
        ("text", Json::str(text)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{deviation, render_figure_csv, Table};

    fn sample_table() -> String {
        let mut t = Table::new("Table X: demo", &["instr", "paper", "sim", "dev"]);
        t.row(vec!["a".into(), "100.0".into(), "110.0".into(), deviation(110.0, 100.0)]);
        t.row(vec!["b".into(), "50.0".into(), "49.0".into(), deviation(49.0, 50.0)]);
        t.render()
    }

    #[test]
    fn tables_round_trip_through_json() {
        let parsed = parse_tables(&sample_table());
        assert_eq!(parsed.len(), 1);
        let t = &parsed[0];
        assert_eq!(t.get_str("title"), Some("Table X: demo"));
        let headers = t.get("headers").unwrap().as_arr().unwrap();
        assert_eq!(headers.len(), 4);
        assert_eq!(headers[3].as_str(), Some("dev"));
        let rows = t.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_arr().unwrap()[3].as_str(), Some("+10.0%"));
    }

    #[test]
    fn csv_blocks_parse_numbers_and_inf() {
        let csv = render_figure_csv(
            "ilp",
            &[1.0, 2.0],
            &[("4w", vec![10.0, 20.0]), ("8w", vec![30.0, f64::INFINITY])],
        );
        let text = format!("## Fig\n\ncsv:\n{csv}\nafter\n");
        let blocks = parse_csv_blocks(&text);
        assert_eq!(blocks.len(), 1);
        let rows = blocks[0].get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].as_arr().unwrap()[1].as_f64(), Some(20.0));
        assert_eq!(rows[1].as_arr().unwrap()[2].as_str(), Some("inf"));
    }

    #[test]
    fn deviation_stats_aggregate() {
        let stats = deviation_stats(&sample_table()).unwrap();
        assert_eq!(stats.cells, 2);
        assert!((stats.mean_abs_pct - 6.0).abs() < 1e-9, "{stats:?}");
        assert!((stats.max_abs_pct - 10.0).abs() < 1e-9, "{stats:?}");
        assert!(deviation_stats("no tables here\n").is_none());
    }

    #[test]
    fn report_json_shape() {
        let j = report_to_json("tX", "demo table", &sample_table());
        assert_eq!(j.get_str("id"), Some("tX"));
        assert_eq!(j.get_str("title"), Some("Table X: demo"));
        assert_eq!(j.get("tables").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("deviation").unwrap().get_f64("max_abs_pct").is_some());
        // and it serializes to parseable JSON
        let s = j.to_string();
        assert!(crate::util::Json::parse(&s).is_ok());
    }

    #[test]
    fn bench_json_shape() {
        use crate::workload::{Plan, SimRunner, Workload};
        let w = Workload::parse_spec("ld.shared u32 4").unwrap();
        let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
        let j = bench_to_json(&r);
        assert_eq!(j.get_str("workload"), Some("ld.shared u32 4"));
        assert_eq!(j.get_str("kind"), Some("ld.shared"));
        assert_eq!(j.get_str("throughput_unit"), Some("bytes/clk/SM"));
        assert_eq!(j.get("device").unwrap().get_str("name"), Some("a100"));
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].get_str("unit"), Some("point"));
        // Table 10: a 4-way conflicted u32 load takes ~29 cycles
        let lat = units[0].get_f64("latency").unwrap();
        assert!((lat - 29.0).abs() < 1.5, "{lat}");
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn lint_json_shape() {
        use crate::workload::{Plan, Workload};
        let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
        let plan = Plan::new(w).point(4, 2).compile().unwrap();
        let scopes = vec![("mma bf16 f32 m16n8k16".to_string(), plan.lint())];
        let j = lint_to_json(&scopes);
        assert_eq!(j.get_str("schema"), Some("tcbench/lint/v1"));
        assert_eq!(j.get_f64("errors"), Some(0.0));
        assert_eq!(j.get_f64("warnings"), Some(0.0));
        let scopes = j.get("scopes").unwrap().as_arr().unwrap();
        assert_eq!(scopes.len(), 1);
        assert_eq!(scopes[0].get_str("scope"), Some("mma bf16 f32 m16n8k16"));
        assert!(Json::parse(&j.to_string()).is_ok());

        // a result's diagnostics array is always present (empty without
        // debug findings), so consumers can rely on the field
        let r = plan.run(&crate::workload::SimRunner, 1).unwrap();
        let bench = bench_to_json(&r);
        assert!(bench.get("diagnostics").unwrap().as_arr().is_some());
    }

    #[test]
    fn real_experiment_reports_structure() {
        // a sim experiment with a dev column and a figure with csv
        let runner = crate::workload::SimRunner;
        let t10 = crate::coordinator::run_experiment("t10", &runner).unwrap();
        let j = report_to_json("t10", "ld.shared bank-conflict latency", &t10);
        assert!(!j.get("tables").unwrap().as_arr().unwrap().is_empty());
        assert!(j.get("deviation").unwrap().get_f64("mean_abs_pct").is_some());

        let fig7 = crate::coordinator::run_experiment("fig7", &runner).unwrap();
        let j = report_to_json("fig7", "mma.m16n8k8 sweep on A100", &fig7);
        assert!(!j.get("figures").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn profiled_bench_units_carry_a_profile_section() {
        use crate::sim::ProfileMode;
        use crate::workload::{Plan, SimRunner, Workload};
        let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
        let plan = Plan::new(w).point(4, 2).compile().unwrap();
        let off = bench_to_json(&plan.run(&SimRunner, 1).unwrap());
        assert!(off.get("units").unwrap().as_arr().unwrap()[0].get("profile").is_none());

        let r = plan.run_profiled(&SimRunner, 1, ProfileMode::Counting).unwrap();
        let j = bench_to_json(&r);
        let unit = &j.get("units").unwrap().as_arr().unwrap()[0];
        let p = unit.get("profile").expect("profiled unit carries a profile section");
        let warp_cycles = p.get_f64("warp_cycles").unwrap();
        let category_sum: f64 = p
            .get("categories")
            .unwrap()
            .as_obj()
            .unwrap()
            .values()
            .map(|v| v.as_f64().unwrap())
            .sum();
        assert_eq!(category_sum, warp_cycles);
        let fraction_sum: f64 = p
            .get("fractions")
            .unwrap()
            .as_obj()
            .unwrap()
            .values()
            .map(|v| v.as_f64().unwrap())
            .sum();
        assert!((fraction_sum - 1.0).abs() < 1e-9, "{fraction_sum}");
        assert_eq!(p.get_f64("trace_events"), Some(0.0)); // Counting keeps no timeline
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn numeric_unit_outputs_serialize_to_valid_json() {
        use crate::workload::{Plan, SimRunner, Workload};
        // a chain that overflows produces non-finite errors; the JSON
        // encoding must stay parseable (strings, not bare inf/NaN)
        let w = Workload::parse_spec("numeric chain fp16 f16 14").unwrap();
        let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
        let j = bench_to_json(&r);
        assert_eq!(j.get_str("kind"), Some("numeric"));
        assert_eq!(j.get_str("throughput_unit"), Some("l2 rel err"));
        let unit = &j.get("units").unwrap().as_arr().unwrap()[0];
        assert_eq!(unit.get_str("unit"), Some("numeric"));
        assert_eq!(unit.get_str("probe"), Some("chain"));
        assert!(unit.get_f64("overflow_at").is_some(), "FP16 chain overflows: {unit}");
        let reparsed = Json::parse(&j.to_string()).expect("valid JSON despite inf");
        assert_eq!(reparsed.get_str("kind"), Some("numeric"));

        let w = Workload::parse_spec("numeric profile bf16 f32 acc fp32").unwrap();
        let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
        let j = bench_to_json(&r);
        let unit = &j.get("units").unwrap().as_arr().unwrap()[0];
        assert_eq!(unit.get_str("probe"), Some("profile"));
        assert_eq!(unit.get_str("op"), Some("acc"));
        assert_eq!(unit.get_str("init"), Some("fp32"));
        assert!(unit.get_f64("mean_abs_err").unwrap() > 0.0);
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
