//! Integration: the coordinator regenerates every registered experiment
//! end-to-end (native backend; the PJRT path is exercised in
//! numerics_backends.rs and by `repro all`).

use tcbench::coordinator::{run_experiment, EXPERIMENTS};
use tcbench::workload::SimRunner;

#[test]
fn every_simulator_experiment_renders() {
    for e in EXPERIMENTS.iter().filter(|e| !e.numeric) {
        let report = run_experiment(e.id, &SimRunner)
            .unwrap_or_else(|err| panic!("{}: {err:#}", e.id));
        assert!(report.contains("##"), "{} report missing title", e.id);
        assert!(report.len() > 200, "{} report suspiciously short", e.id);
    }
}

#[test]
fn numeric_experiments_render_on_native_backend() {
    for id in ["t12", "t13", "t14", "t15"] {
        let report = run_experiment(id, &SimRunner).unwrap();
        assert!(report.contains("multiplication"), "{id}:\n{report}");
        assert!(report.contains("accumulation"), "{id}");
    }
}

#[test]
fn fig17_reports_fp16_overflow() {
    let report = run_experiment("fig17", &SimRunner).unwrap();
    assert!(
        report.contains("overflow (inf) at N ="),
        "fig17 must flag the FP16 overflow:\n{report}"
    );
    assert!(report.contains("csv:"));
}

#[test]
fn sweep_figures_contain_all_warp_series() {
    let report = run_experiment("fig6", &SimRunner).unwrap();
    for w in ["1w", "2w", "4w", "6w", "8w", "12w", "16w", "32w"] {
        assert!(report.contains(w), "fig6 missing series {w}");
    }
}

#[test]
fn appendix_tables_report_speedups() {
    let t16 = run_experiment("t16", &SimRunner).unwrap();
    assert!(t16.contains("mma_pipeline.cu") && t16.contains("speedup"));
    let t17 = run_experiment("t17", &SimRunner).unwrap();
    assert!(t17.contains("mma_permuted.cu"));
}
