//! Integration: robustness under injected faults and deadlines, over
//! real sockets against `repro serve` subprocesses.
//!
//! Four contracts:
//!   1. `store.read:err` faults degrade to misses — a chaotic replica
//!      re-simulates and stays bit-identical to a fault-free one, and
//!      the injections are observable in `/v1/metrics`.
//!   2. A blown `deadline_ms` degrades plan units to the calibrated
//!      analytic prediction (200, marked, never cached); the same plan
//!      without a deadline serves the simulated value unmarked.
//!   3. `sim:panic` faults surface as typed 500 `internal` responses —
//!      the worker pool absorbs the panic and the server stays healthy.
//!   4. `queue:full` sheds are retried by loadgen with backoff, and the
//!      extended accounting identity still balances the books.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use tcbench::device;
use tcbench::loadgen::{self, http_request, LoadgenConfig};
use tcbench::util::Json;
use tcbench::workload::{self, Workload};

/// A per-test scratch tree under the target-adjacent temp dir.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tcbench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

struct Served {
    child: Child,
    addr: String,
}

impl Served {
    /// Spawn `repro serve --addr 127.0.0.1:0` plus `extra` flags (the
    /// chaos spec, a cell store, ...) and parse the bound address from
    /// the startup banner on stderr.
    fn spawn(cwd: &Path, extra: &[&str]) -> Served {
        std::fs::create_dir_all(cwd).expect("server cwd");
        let mut args = vec!["serve", "--addr", "127.0.0.1:0", "--threads", "2"];
        args.extend_from_slice(extra);
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(&args)
            .current_dir(cwd)
            .stdout(Stdio::null())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn repro serve");
        let stderr = child.stderr.take().expect("stderr piped");
        let mut banner = String::new();
        let mut addr = None;
        for line in BufReader::new(stderr).lines() {
            let line = line.expect("read server stderr");
            banner.push_str(&line);
            banner.push('\n');
            if let Some(rest) = line.split("listening on http://").nth(1) {
                let end = rest.find(|c: char| c.is_whitespace()).unwrap_or(rest.len());
                addr = Some(rest[..end].to_string());
                break;
            }
            if banner.len() > 16_384 {
                break;
            }
        }
        let addr = addr.unwrap_or_else(|| {
            let _ = child.kill();
            panic!("no listening banner from repro serve; stderr so far:\n{banner}")
        });
        Served { child, addr }
    }

    /// One round trip; the caller judges the status (faults are the
    /// point of this file, so non-200s are data, not errors).
    fn post(&self, path: &str, body: &str) -> (u16, Json) {
        let (status, response) =
            http_request(&self.addr, "POST", path, body).expect("http round trip");
        (status, Json::parse(&response).expect("JSON body"))
    }

    fn metrics(&self) -> Json {
        let (status, response) =
            http_request(&self.addr, "GET", "/v1/metrics", "").expect("metrics scrape");
        assert_eq!(status, 200);
        Json::parse(&response).expect("JSON").get("data").expect("data").clone()
    }
}

impl Drop for Served {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// The (latency, throughput) bit patterns of every cell in a sweep
/// response — what must survive injected store faults untouched.
fn cell_bits(result: &Json) -> Vec<(u64, u64)> {
    result
        .get("cells")
        .expect("cells")
        .as_arr()
        .expect("cells array")
        .iter()
        .map(|c| (c.get_f64("latency").unwrap().to_bits(), c.get_f64("throughput").unwrap().to_bits()))
        .collect()
}

fn data_of(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    j.get("data").unwrap_or_else(|| panic!("no data in {j}")).clone()
}

#[test]
fn store_read_faults_degrade_to_misses_and_stay_bit_identical() {
    let base = scratch("chaos_store");
    let cells = base.join("cells");
    let cells_flag = cells.to_str().unwrap().to_string();
    let sweep_body = r#"{"instr":"ldmatrix x2","device":"a100"}"#;

    // fault-free replica seeds the shared store and fixes the truth
    let bits_clean;
    {
        let a = Served::spawn(&base.join("a"), &["--cell-store", &cells_flag]);
        let (status, j) = a.post("/v1/sweep", sweep_body);
        assert_eq!(status, 200, "{j}");
        bits_clean = cell_bits(data_of(&j).get("result").expect("result"));
        assert!(!bits_clean.is_empty());
    }

    // chaotic replica: half its store reads fail — every injected err
    // must degrade to a miss and re-simulate to the identical bits
    let b = Served::spawn(
        &base.join("b"),
        &["--cell-store", &cells_flag, "--chaos", "store.read:err@0.5", "--chaos-seed", "3"],
    );
    let (status, j) = b.post("/v1/sweep", sweep_body);
    assert_eq!(status, 200, "{j}");
    let bits_chaotic = cell_bits(data_of(&j).get("result").expect("result"));
    assert_eq!(bits_clean, bits_chaotic, "store faults must never change served numbers");

    let m = b.metrics();
    let chaos = m.get("chaos").expect("chaos section");
    assert_eq!(chaos.get("enabled").and_then(Json::as_bool), Some(true), "{m}");
    assert_eq!(chaos.get_str("spec"), Some("store.read:err@0.5"), "{m}");
    assert!(chaos.get_u64("injected_total").unwrap() > 0, "no faults fired: {m}");
    assert!(
        chaos.get("by_fault").unwrap().get_u64("store.read:err").unwrap() > 0,
        "{m}"
    );
    drop(b);

    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn blown_deadlines_degrade_to_the_analytic_prediction_over_the_wire() {
    let base = scratch("chaos_deadline");
    let s = Served::spawn(&base, &[]);

    let (status, j) = s.post(
        "/v1/plan",
        r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
            "points":[[4,2]],"backend":"native","deadline_ms":0}"#,
    );
    assert_eq!(status, 200, "{j}");
    let unit = data_of(&j).get("units").expect("units").as_arr().expect("array")[0].clone();
    let marker = unit.get("degraded").expect("degraded marker").clone();
    assert_eq!(marker.get("predicted").and_then(Json::as_bool), Some(true), "{j}");
    // the served numbers are bit-exactly the closed-form prediction the
    // client could not have waited for the simulator to confirm
    let load = Workload::parse_spec("mma fp16 f32 m16n8k16").unwrap();
    let dev = device::by_name("a100").unwrap();
    let pred = load.predict(&dev, workload::ExecPoint::new(4, 2)).unwrap();
    let result = unit.get("result").expect("result");
    assert_eq!(result.get_f64("latency"), Some(pred.latency), "{j}");
    assert_eq!(result.get_f64("throughput"), Some(pred.throughput), "{j}");

    // the degraded payload was not cached: the unhurried retry of the
    // same plan simulates for real and serves an unmarked unit
    let (status, j) = s.post(
        "/v1/plan",
        r#"{"workload":"mma fp16 f32 m16n8k16","device":"a100",
            "points":[[4,2]],"backend":"native"}"#,
    );
    assert_eq!(status, 200, "{j}");
    let unit = data_of(&j).get("units").expect("units").as_arr().expect("array")[0].clone();
    assert!(unit.get("degraded").is_none(), "{j}");

    // both metric surfaces observed the degradation
    let m = s.metrics();
    let rob = m.get("robustness").expect("robustness section");
    assert!(rob.get_u64("degraded_total").unwrap() >= 1, "{m}");
    assert!(rob.get("degraded_by_family").unwrap().get_u64("mma").unwrap() >= 1, "{m}");
    let (status, prom) = http_request(&s.addr, "GET", "/metrics", "").expect("prometheus scrape");
    assert_eq!(status, 200);
    let line = prom
        .lines()
        .find(|l| l.starts_with("tcserved_degraded_total "))
        .unwrap_or_else(|| panic!("tcserved_degraded_total missing:\n{prom}"));
    assert!(!line.ends_with(" 0"), "{line}");
}

#[test]
fn sim_panics_become_typed_internal_errors_and_the_server_survives() {
    let base = scratch("chaos_panic");
    let s = Served::spawn(&base, &["--chaos", "sim:panic@1.0", "--chaos-seed", "11"]);

    let (status, j) = s.post("/v1/sweep", r#"{"instr":"ldmatrix x1","device":"a100"}"#);
    assert_eq!(status, 500, "{j}");
    let err = j.get("error").expect("error object");
    assert_eq!(err.get_str("code"), Some("internal"), "{j}");

    // the panic was absorbed by the worker, not the process: liveness
    // and the fault ledger are both still being served
    let (status, body) = http_request(&s.addr, "GET", "/healthz", "").expect("healthz");
    assert_eq!(status, 200, "{body}");
    let m = s.metrics();
    let chaos = m.get("chaos").expect("chaos section");
    assert!(chaos.get("by_fault").unwrap().get_u64("sim:panic").unwrap() >= 1, "{m}");
}

#[test]
fn loadgen_retries_queue_sheds_and_the_accounting_identity_balances() {
    let base = scratch("chaos_queue");
    let s = Served::spawn(&base, &["--chaos", "queue:full@0.3", "--chaos-seed", "7"]);

    let cfg = LoadgenConfig {
        addr: s.addr.clone(),
        mix: loadgen::parse_mix("plan").unwrap(),
        concurrency: 2,
        duration_secs: 1.5,
        retries: 3,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert!(report.requests > 0, "no traffic generated");
    // every logical request lands in exactly one terminal bucket
    let accounted = report.ok
        + report.retried_ok
        + report.rejected
        + report.gave_up
        + report.http_errors
        + report.transport_errors;
    assert_eq!(accounted, report.requests, "{report:?}");
    assert!(report.ok + report.retried_ok > 0, "nothing succeeded under chaos: {report:?}");
    assert_eq!(report.transport_errors, 0, "{report:?}");
    assert!(report.attempts >= report.requests, "{report:?}");
    // with a 30% shed rate over this many requests, retries fired; with
    // a non-zero budget, final 503s are gave_up, never rejected
    assert!(report.attempts > report.requests, "no retry ever fired: {report:?}");
    assert_eq!(report.rejected, 0, "non-zero retry budget must classify 503s as gave_up");

    let m = s.metrics();
    let chaos = m.get("chaos").expect("chaos section");
    assert!(chaos.get("by_fault").unwrap().get_u64("queue:full").unwrap() >= 1, "{m}");
    drop(s);
    let _ = std::fs::remove_dir_all(&base);
}
