//! Calibration gate for the analytic fast path: the closed-form model
//! must stay within the pinned per-family error bounds
//! ([`CALIBRATION_BOUNDS`]) of the cycle simulator across every timing
//! family's sweep grid on every registry device. Drift on either side —
//! a model edit or a simulator change — fails this suite, and with it
//! CI. The suite also pins the tentpole perf claim: scoring a config
//! analytically must be at least 100x faster than simulating it.

use std::time::Instant;

use tcbench::device::{self, Device, FpuFallback};
use tcbench::isa::{shapes, AbType, CdType, MmaInstr};
use tcbench::microbench::measure_mma;
use tcbench::sim::{calibration_bound, predict_mma, CALIBRATION_BOUNDS};
use tcbench::workload::{ExecPoint, Workload};

/// Every legal (warps, ilp) cell of the workload's sweep grid.
fn grid(w: &Workload) -> Vec<ExecPoint> {
    let mut cells = Vec::new();
    for &warps in &w.sweep_warps_axis() {
        for &ilp in &w.sweep_ilp_axis() {
            let p = ExecPoint::new(warps, ilp);
            if w.validate_point(p).is_ok() {
                cells.push(p);
            }
        }
    }
    cells
}

/// Predict and simulate every grid cell of `w` on `dev`, asserting the
/// family's pinned bound admits each pair.
fn assert_family_calibrated(w: &Workload, dev: &Device) {
    let bound = calibration_bound(w.kind())
        .unwrap_or_else(|| panic!("no calibration bound for family {}", w.kind()));
    let cells = grid(w);
    assert!(!cells.is_empty(), "{}: empty grid for {}", dev.name, w.to_spec());
    for p in cells {
        let pred = w.predict(dev, p).unwrap_or_else(|e| {
            panic!("{}: {} w={} ilp={}: {e}", dev.name, w.to_spec(), p.warps, p.ilp)
        });
        let sim = w.measure_cached(dev, p, "sim");
        let abs = (sim.latency - pred.latency).abs();
        assert!(
            bound.admits(pred.latency, sim.latency),
            "{}: {} w={} ilp={}: predicted {:.2} vs simulated {:.2} breaks the {:?} bound \
             (rel {:.3} > {}, abs {:.2} > {})",
            dev.name,
            w.to_spec(),
            p.warps,
            p.ilp,
            pred.latency,
            sim.latency,
            bound.family,
            abs / pred.latency.max(f64::MIN_POSITIVE),
            bound.max_rel,
            abs,
            bound.max_abs
        );
    }
}

/// Dense and sparse mma across the full 48-cell grid on every device.
/// Fallback-free instructions only, mirroring the property-test filter:
/// FPU-fallback shapes time as CUDA-core loops the latency model does
/// not cover.
#[test]
fn mma_families_stay_within_the_pinned_bounds() {
    for dev in device::registry() {
        let dense: Vec<MmaInstr> = dev
            .mma_timings
            .iter()
            .filter(|(i, t)| !i.sparse && t.fpu_fallback == FpuFallback::No)
            .map(|(i, _)| *i)
            .take(3)
            .collect();
        let sparse: Vec<MmaInstr> = dev
            .mma_timings
            .iter()
            .filter(|(i, t)| i.sparse && t.fpu_fallback == FpuFallback::No)
            .map(|(i, _)| *i)
            .take(2)
            .collect();
        assert!(!dense.is_empty(), "{}: no dense non-fallback instructions", dev.name);
        for instr in dense.iter().chain(&sparse) {
            let w = if instr.sparse {
                Workload::MmaSp { ab: instr.ab, cd: instr.cd, shape: instr.shape }
            } else {
                Workload::Mma { ab: instr.ab, cd: instr.cd, shape: instr.shape }
            };
            assert_family_calibrated(&w, &dev);
        }
    }
}

#[test]
fn ldmatrix_family_stays_within_the_pinned_bounds() {
    let mut covered = 0;
    for dev in device::registry() {
        for spec in ["ldmatrix x1", "ldmatrix x2", "ldmatrix x4"] {
            let w = Workload::parse_spec(spec).unwrap();
            if w.validate(&dev).is_err() {
                continue;
            }
            assert_family_calibrated(&w, &dev);
            covered += 1;
        }
    }
    assert!(covered >= 3, "ldmatrix calibration covered only {covered} device/spec combos");
}

#[test]
fn ld_shared_family_stays_within_the_pinned_bounds() {
    for dev in device::registry() {
        for spec in ["ld.shared u32 1", "ld.shared u32 4", "ld.shared u32 8", "ld.shared u64 2"] {
            let w = Workload::parse_spec(spec).unwrap();
            if w.validate(&dev).is_err() {
                continue;
            }
            assert_family_calibrated(&w, &dev);
        }
    }
}

/// wmma times through its 2-instruction HMMA lowering, so it is only
/// predictable on devices whose timing table carries the lowered piece.
#[test]
fn wmma_family_stays_within_the_pinned_bounds() {
    let mut covered = 0;
    for dev in device::registry() {
        let w = Workload::parse_spec("wmma fp16 f32 m16n16k16").unwrap();
        if w.validate(&dev).is_err() || w.predict(&dev, ExecPoint::new(1, 1)).is_err() {
            continue;
        }
        assert_family_calibrated(&w, &dev);
        covered += 1;
    }
    assert!(covered >= 1, "wmma calibration covered no device");
}

/// All three Appendix-A variants at size 512, over the tile-legal
/// warps x stages grid, on every device that can run them (cp.async
/// pipelines need Ampere).
#[test]
fn gemm_family_stays_within_the_pinned_bounds() {
    let specs = [
        "gemm baseline bf16 f32 512 128x128x32",
        "gemm pipeline bf16 f32 512 128x128x32",
        "gemm pipeline fp16 f32 512 64x64x32",
        "gemm permuted bf16 f32 512 128x128x32 l2",
    ];
    let mut covered = 0;
    for dev in device::registry() {
        for spec in specs {
            let w = Workload::parse_spec(spec).unwrap();
            if w.validate(&dev).is_err() {
                continue;
            }
            assert_family_calibrated(&w, &dev);
            covered += 1;
        }
    }
    assert!(covered >= specs.len(), "gemm calibration covered only {covered} device/spec combos");
}

#[test]
fn every_timing_family_has_a_pinned_bound() {
    for family in ["mma", "mma.sp", "ldmatrix", "ld.shared", "wmma", "gemm"] {
        assert!(calibration_bound(family).is_some(), "no bound for {family}");
    }
    // numeric probes measure error, not cycles: nothing to calibrate
    assert!(calibration_bound("numeric").is_none());
    assert_eq!(CALIBRATION_BOUNDS.len(), 5);
}

/// The tentpole perf claim behind `/v1/tune`'s pruning: the analytic
/// scorer must be at least 100x faster (configs/sec) than confirming
/// the same configs on the cycle simulator. Measured over the canonical
/// 48-cell mma grid; the real margin is orders of magnitude larger, so
/// 100x is a conservative floor even on slow shared CI runners.
#[test]
fn analytic_scoring_is_at_least_100x_faster_than_the_cycle_sim() {
    let dev = device::a100();
    let instr = MmaInstr::dense(AbType::Fp16, CdType::Fp32, shapes::M16N8K16);
    let w = Workload::Mma { ab: instr.ab, cd: instr.cd, shape: instr.shape };
    let cells = grid(&w);
    assert_eq!(cells.len(), 48);

    // one uncached simulated pass (measure_mma bypasses the cell cache,
    // so test ordering cannot turn this into warm lookups)
    let t0 = Instant::now();
    for p in &cells {
        std::hint::black_box(measure_mma(&dev, &instr, p.warps, p.ilp));
    }
    let sim_secs = t0.elapsed().as_secs_f64().max(1e-9);

    // many analytic passes over the same grid, so clock resolution does
    // not dominate the numerator
    let reps = 200u32;
    let t0 = Instant::now();
    for _ in 0..reps {
        for p in &cells {
            std::hint::black_box(predict_mma(&dev, &instr, p.warps, p.ilp).unwrap());
        }
    }
    let ana_secs = t0.elapsed().as_secs_f64().max(1e-9);

    let sim_rate = cells.len() as f64 / sim_secs;
    let ana_rate = cells.len() as f64 * reps as f64 / ana_secs;
    assert!(
        ana_rate >= 100.0 * sim_rate,
        "analytic scorer at {ana_rate:.0} configs/s is not 100x the sim's {sim_rate:.0} configs/s"
    );
}
