//! End-to-end assertions of the paper's *conclusions* (§9): each bullet
//! of the paper's summary must hold in the reproduced system.

use tcbench::device::{a100, rtx2080ti, rtx3070ti};
use tcbench::gemm::{table16, table17, GemmConfig};
use tcbench::isa::shapes::*;
use tcbench::isa::{AbType, CdType, MmaInstr};
use tcbench::microbench::{completion_latency_mma, measure_mma, sweep_mma};
use tcbench::numerics::{chain_errors, NativeExec, NumericCfg};

/// "Sparse operation doubles the throughput … while using the same
/// number of execution cycles."
#[test]
fn conclusion_sparse_doubles_throughput_same_latency() {
    let d = a100();
    let dense = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
    let sp = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
    assert_eq!(
        completion_latency_mma(&d, &dense),
        completion_latency_mma(&d, &sp)
    );
    let md = measure_mma(&d, &dense, 8, 2);
    let ms = measure_mma(&d, &sp, 8, 2);
    let ratio = ms.throughput / md.throughput;
    assert!((1.9..2.1).contains(&ratio), "ratio {ratio}");
}

/// "For some instructions peak performance can only be achieved when
/// there are at least eight warps" (Fig. 7 / m16n8k8).
#[test]
fn conclusion_eight_warps_needed_for_small_k() {
    let d = a100();
    let i = MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K8);
    let s = sweep_mma(&d, &i);
    let best4: f64 = (1..=6)
        .map(|ilp| s.cell(4, ilp).unwrap().throughput)
        .fold(0.0, f64::max);
    let best8: f64 = (1..=6)
        .map(|ilp| s.cell(8, ilp).unwrap().throughput)
        .fold(0.0, f64::max);
    assert!(
        best8 > 1.15 * best4,
        "8-warp best {best8} must clearly beat 4-warp best {best4}"
    );
}

/// "The instructions with smaller k give an undesired performance on
/// A100 … However [on RTX3070Ti] the instruction with a smaller k can
/// also reach the same throughput."
#[test]
fn conclusion_sparse_small_k_device_dependent() {
    let a = a100();
    let g = rtx3070ti();
    let small = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K16);
    let big = MmaInstr::sp(AbType::Fp16, CdType::Fp32, M16N8K32);
    // A100: small k well below the sparse peak
    let a_small = measure_mma(&a, &small, 8, 2).throughput;
    let a_big = measure_mma(&a, &big, 8, 2).throughput;
    assert!(a_small < 0.75 * a_big, "A100 {a_small} vs {a_big}");
    // RTX3070Ti: both reach the same converged throughput
    let g_small = measure_mma(&g, &small, 8, 1).throughput;
    let g_big = measure_mma(&g, &big, 8, 1).throughput;
    assert!(
        (g_small / g_big - 1.0).abs() < 0.05,
        "3070Ti {g_small} vs {g_big}"
    );
}

/// "RTX3070Ti Tensor Cores favor FP16 as an accumulation data type …
/// but there is no difference … on A100."
#[test]
fn conclusion_accumulator_type_rule() {
    let a = a100();
    let g = rtx3070ti();
    let f32acc = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16);
    let f16acc = MmaInstr::dense(AbType::Fp16, CdType::Fp16, M16N8K16);
    let a32 = measure_mma(&a, &f32acc, 8, 2).throughput;
    let a16 = measure_mma(&a, &f16acc, 8, 2).throughput;
    assert!((a32 / a16 - 1.0).abs() < 0.05, "A100: {a32} vs {a16}");
    let g32 = measure_mma(&g, &f32acc, 8, 1).throughput;
    let g16 = measure_mma(&g, &f16acc, 8, 1).throughput;
    assert!((g16 / g32 - 2.0).abs() < 0.2, "3070Ti: {g16} vs {g32}");
}

/// "Dense FMA latency of Ampere … does not improve compared to Turing."
#[test]
fn conclusion_latency_stagnant_across_generations() {
    let i = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K8);
    let turing = completion_latency_mma(&rtx2080ti(), &i);
    let ampere = completion_latency_mma(&a100(), &i);
    assert!((turing - ampere).abs() <= 1.0, "{turing} vs {ampere}");
}

/// "BF16 … performance same as FP16; FP16 suffers from a smaller range
/// and BF16 from higher numeric errors."
#[test]
fn conclusion_bf16_vs_fp16_tradeoff() {
    let d = a100();
    // identical performance
    let bf = measure_mma(&d, &MmaInstr::dense(AbType::Bf16, CdType::Fp32, M16N8K16), 8, 2);
    let fp = measure_mma(&d, &MmaInstr::dense(AbType::Fp16, CdType::Fp32, M16N8K16), 8, 2);
    assert_eq!(bf.latency, fp.latency);
    assert_eq!(bf.throughput, fp.throughput);
    // numeric trade-off (chain study)
    let bf_chain = chain_errors(
        &mut NativeExec::new(NumericCfg::new("bf16", "f32", 16, 8, 8)),
        8, 64, true, 3,
    );
    let fp_chain = chain_errors(
        &mut NativeExec::new(NumericCfg::new("fp16", "f16", 16, 8, 8)),
        14, 64, true, 3,
    );
    assert!(bf_chain.overflow_at.is_none(), "BF16 keeps FP32's range");
    let at = fp_chain.overflow_at.expect("FP16 overflows");
    assert!(at >= 7, "overflow at {at}");
    // compare error levels safely before the overflow region
    assert!(
        bf_chain.rel_err[5] > 2.0 * fp_chain.rel_err[5],
        "bf16 {} vs fp16 {}",
        bf_chain.rel_err[5],
        fp_chain.rel_err[5]
    );
}

/// Appendix A: async staging ≈2x and permuted layout ≈3x (shape-level:
/// both clearly win, permuted wins the most per its table).
#[test]
fn conclusion_appendix_ablations() {
    let d = a100();
    let cfg = GemmConfig { size: 512, ..GemmConfig::default() };
    let (b16, p16) = table16(&d, cfg);
    let s_async = b16.cta_cycles as f64 / p16.cta_cycles as f64;
    assert!((1.4..2.6).contains(&s_async), "async {s_async}");
    let (b17, p17) = table17(&d, cfg);
    let s_perm = b17.cta_cycles as f64 / p17.cta_cycles as f64;
    assert!((1.8..3.8).contains(&s_perm), "permuted {s_perm}");
}

/// The m8n8k4 FPU fallback on Ampere runs far below Tensor-Core rates
/// (§2.2: "10x slower than the expected Tensor Cores performance").
#[test]
fn conclusion_m8n8k4_fpu_fallback() {
    let d = a100();
    let fallback = MmaInstr::dense(AbType::Fp16, CdType::Fp32, M8N8K4);
    let m = measure_mma(&d, &fallback, 8, 2);
    // 256 FMA/instr at FPU rates: far below the 1024 FMA/clk TC peak.
    assert!(m.throughput < 150.0, "fpu fallback too fast: {}", m.throughput);
    // Turing executes the same shape on its Tensor Cores.
    let t = rtx2080ti();
    let mt = measure_mma(&t, &fallback, 8, 2);
    assert!(mt.throughput > 2.0 * m.throughput);
}
