//! Integration: the §8 numeric workload family end to end — the
//! plan-backed tables 12–15 and Fig. 17 pinned against
//! `report::expected` and against the direct `numerics::` datapath
//! (folding the studies into the Workload layer must not change a
//! single number), plus the chain/init sweep axes and fp8 device
//! gating.

use tcbench::coordinator::run_experiment;
use tcbench::numerics::{
    chain_errors, profile_op, InitKind, NativeExec, ProfileOp,
};
use tcbench::report::expected;
use tcbench::workload::{
    Plan, SimRunner, Workload, CHAIN_SEED, CHAIN_TRIALS, PROFILE_SEED, PROFILE_TRIALS,
};

/// Render one probe result exactly like the experiment tables do.
fn fmt2e(v: f64) -> String {
    format!("{v:.2e}")
}

#[test]
fn numeric_tables_are_plan_backed_and_pinned_to_the_legacy_values() {
    // For every row of the paper's numeric tables (expected.rs), the
    // plan-backed report must contain the *identical* measured value the
    // legacy direct path produced: same probe semantics, same trials
    // (1000), same seed (7).
    for row in expected::numeric_tables() {
        let id = match row.table {
            "12" => "t12",
            "13" => "t13",
            "14" => "t14",
            "15" => "t15",
            other => panic!("unknown table {other}"),
        };
        let report = run_experiment(id, &SimRunner).unwrap();
        let (ab, cd) = match row.cfg {
            "bf16_f32" => ("bf16", "f32"),
            "fp16_f32" => ("fp16", "f32"),
            "fp16_f16" => ("fp16", "f16"),
            "tf32_f32" => ("tf32", "f32"),
            other => panic!("unknown cfg {other}"),
        };
        let init = if row.init == "low" { "low" } else { "fp32" };
        for (op, paper) in [
            (ProfileOp::Multiplication, row.mul),
            (ProfileOp::InnerProduct, row.inner),
            (ProfileOp::Accumulation, row.accum),
        ] {
            // the workload-layer measurement...
            let spec = format!("numeric profile {ab} {cd} {} {init}", op.spec_name());
            let w = Workload::parse_spec(&spec).unwrap();
            let plan = Plan::new(w).point(1, 1).compile().unwrap();
            let res = plan.run(&SimRunner, 1).unwrap();
            let via_plan = res.profile().expect("profile unit").mean_abs_err;
            // ...equals the direct numerics:: call bit-for-bit...
            let init_kind =
                if init == "low" { InitKind::LowPrecision } else { InitKind::Fp32 };
            let direct = profile_op(
                &mut NativeExec::new(
                    tcbench::numerics::NumericCfg::new(ab, cd, 16, 8, 8),
                ),
                op,
                init_kind,
                PROFILE_TRIALS,
                PROFILE_SEED,
            );
            assert_eq!(
                via_plan.to_bits(),
                direct.mean_abs_err.to_bits(),
                "{spec}: plan {via_plan:e} vs direct {:e}",
                direct.mean_abs_err
            );
            // ...and both the paper value and the measured value appear
            // in the rendered table
            assert!(report.contains(&fmt2e(paper)), "{id} missing paper {}:\n{report}", fmt2e(paper));
            assert!(
                report.contains(&fmt2e(via_plan)),
                "{id} missing measured {}:\n{report}",
                fmt2e(via_plan)
            );
        }
    }
}

#[test]
fn zero_error_rows_stay_exactly_zero() {
    // Tables 13/15 low-precision rows are exact-zero findings: the plan
    // path must preserve them bit-exactly, not just approximately
    for spec in [
        "numeric profile fp16 f32 mul low",
        "numeric profile fp16 f32 inner low",
        "numeric profile fp16 f32 acc low",
        "numeric profile tf32 f32 mul low",
        "numeric profile tf32 f32 inner low",
        "numeric profile tf32 f32 acc low",
        "numeric profile bf16 f32 mul low",
        "numeric profile bf16 f32 inner low",
    ] {
        let w = Workload::parse_spec(spec).unwrap();
        let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
        assert_eq!(r.profile().unwrap().mean_abs_err, 0.0, "{spec}");
    }
    // the one nonzero low-precision cell: BF16 RZ accumulation (T12)
    let w = Workload::parse_spec("numeric profile bf16 f32 acc low").unwrap();
    let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
    let acc = r.profile().unwrap().mean_abs_err;
    assert!((1e-9..1e-7).contains(&acc), "paper 1.89e-8, got {acc:e}");
}

#[test]
fn fig17_is_plan_backed_and_pinned() {
    let report = run_experiment("fig17", &SimRunner).unwrap();
    // the FP16 chain overflows where the paper says it does
    assert!(report.contains("overflow (inf) at N ="), "{report}");
    assert!(report.contains("csv:"));
    for label in [
        "TF32 (init TF32)",
        "BF16 (init BF16)",
        "FP16 (init FP16)",
        "TF32 (init FP32)",
        "BF16 (init FP32)",
    ] {
        assert!(report.contains(label), "fig17 missing series {label}");
    }
    // the chain probe through the plan path equals the direct call, and
    // its overflow step brackets the paper's N >= 10 finding
    let w = Workload::parse_spec("numeric chain fp16 f16 14").unwrap();
    let r = Plan::new(w).point(1, 1).compile().unwrap().run(&SimRunner, 1).unwrap();
    let chain = r.chain().expect("chain unit");
    let direct = chain_errors(
        &mut NativeExec::new(tcbench::numerics::NumericCfg::new("fp16", "f16", 16, 8, 8)),
        14,
        CHAIN_TRIALS,
        true,
        CHAIN_SEED,
    );
    // bitwise equality: post-overflow steps are NaN, which `==` rejects
    assert_eq!(chain.rel_err.len(), direct.rel_err.len());
    for (i, (a, b)) in chain.rel_err.iter().zip(&direct.rel_err).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {}: {a:e} vs {b:e}", i + 1);
    }
    assert_eq!(chain.overflow_at, direct.overflow_at);
    let at = chain.overflow_at.expect("FP16 chain must overflow");
    let paper = expected::FIG17_FP16_OVERFLOW_N;
    assert!(
        (paper - 2..=paper + 2).contains(&at),
        "overflow at {at}, paper {paper}"
    );
    assert!(report.contains(&format!("overflow (inf) at N = {at}")), "{report}");
}

#[test]
fn numeric_sweeps_cover_chain_and_init_axes() {
    // `repro sweep --instr "numeric chain ..."`'s shape: the sweep grid
    // rides chain step on the first axis and init kind on the second
    let w = Workload::parse_spec("numeric chain bf16 f32 8").unwrap();
    let plan = Plan::new(w).sweep().compile().unwrap();
    let r = plan.run(&SimRunner, 2).unwrap();
    let sweep = r.sweep().unwrap();
    assert_eq!(sweep.warps_axis, (1..=8).collect::<Vec<u32>>());
    assert_eq!(sweep.ilp_axis, vec![1, 2]);
    assert_eq!(sweep.cells.len(), 16);
    // BF16 chain error grows monotonically in range (§8.2) and the FP32
    // init column dominates the low-precision one at every step
    for step in 1..=8u32 {
        let low = sweep.cell(step, 1).unwrap().latency;
        let f32i = sweep.cell(step, 2).unwrap().latency;
        assert!(f32i > low, "step {step}: {f32i:e} <= {low:e}");
    }
}

#[test]
fn fp8_probes_are_device_gated_and_run_on_hopper() {
    let fp8 = Workload::parse_spec("numeric profile fp8e4m3 f32 mul fp32").unwrap();
    // rejected on every measured device, valid on the projected Hopper
    for dev in ["a100", "rtx3070ti", "rtx2080ti"] {
        let err = Plan::new(fp8).device(dev).point(1, 1).compile().unwrap_err();
        assert!(err.contains("FP8"), "{dev}: {err}");
    }
    let plan = Plan::new(fp8).device("hopper-projected").point(1, 1).compile().unwrap();
    let r = plan.run(&SimRunner, 1).unwrap();
    let e4m3 = r.profile().unwrap().mean_abs_err;
    assert!(e4m3 > 0.0);
    // 2 mantissa bits (e5m2) err > 3 bits (e4m3)
    let e5m2_w = Workload::parse_spec("numeric profile fp8e5m2 f32 mul fp32").unwrap();
    let plan = Plan::new(e5m2_w).device("hopper-projected").point(1, 1).compile().unwrap();
    let e5m2 = plan.run(&SimRunner, 1).unwrap().profile().unwrap().mean_abs_err;
    assert!(e5m2 > e4m3, "{e5m2:e} vs {e4m3:e}");
}
