//! Integration: the PJRT-executed AOT artifacts (L1 Pallas kernel
//! lowered through L2 jax) must agree **bit-exactly** with the native
//! Rust softfloat datapath on random batches, for every numeric config —
//! this is the cross-layer contract of the whole stack.
//!
//! Requires `make artifacts`; tests are skipped (with a note) otherwise.

use tcbench::numerics::{profile_op, InitKind, MmaExec, NativeExec, NumericCfg, ProfileOp};
use tcbench::runtime::{ArtifactExec, ArtifactStore};
use tcbench::util::Prng;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping PJRT integration test: {e:#}");
            None
        }
    }
}

const CFGS: [NumericCfg; 5] = [
    NumericCfg::new("bf16", "f32", 16, 8, 16),
    NumericCfg::new("bf16", "f32", 16, 8, 8),
    NumericCfg::new("fp16", "f32", 16, 8, 16),
    NumericCfg::new("fp16", "f16", 16, 8, 8),
    NumericCfg::new("tf32", "f32", 16, 8, 8),
];

#[test]
fn pjrt_matches_native_bit_exactly() {
    let Some(mut store) = store() else { return };
    for cfg in CFGS {
        let batch = 256;
        let mut rng = Prng::new(0xC0FFEE ^ cfg.k as u64);
        let mut a = vec![0.0f32; batch * cfg.m * cfg.k];
        let mut b = vec![0.0f32; batch * cfg.k * cfg.n];
        let mut c = vec![0.0f32; batch * cfg.m * cfg.n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        rng.fill_normal(&mut c);

        let native = NativeExec::new(cfg).run(batch, &a, &b, &c);
        let mut artifact = ArtifactExec::new(&mut store, cfg).expect("artifact load");
        let pjrt = artifact.run(batch, &a, &b, &c);

        assert_eq!(native.len(), pjrt.len());
        for (i, (x, y)) in native.iter().zip(&pjrt).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{}: element {i} differs: native {x:e} vs pjrt {y:e}",
                cfg.artifact_name()
            );
        }
    }
}

#[test]
fn pjrt_matches_native_on_extreme_values() {
    let Some(mut store) = store() else { return };
    let cfg = NumericCfg::new("fp16", "f16", 16, 8, 8);
    let batch = 4;
    // Large magnitudes drive the FP16 saturation path.
    let a = vec![300.0f32; batch * cfg.m * cfg.k];
    let b = vec![300.0f32; batch * cfg.k * cfg.n];
    let c = vec![0.5f32; batch * cfg.m * cfg.n];
    let native = NativeExec::new(cfg).run(batch, &a, &b, &c);
    let mut artifact = ArtifactExec::new(&mut store, cfg).expect("artifact load");
    let pjrt = artifact.run(batch, &a, &b, &c);
    for (x, y) in native.iter().zip(&pjrt) {
        assert!(x.is_infinite() && y.is_infinite() && x.signum() == y.signum());
    }
}

#[test]
fn pjrt_batch_splitting_handles_odd_sizes() {
    let Some(mut store) = store() else { return };
    let cfg = NumericCfg::new("tf32", "f32", 16, 8, 8);
    for batch in [1usize, 255, 256, 257, 600] {
        let mut rng = Prng::new(batch as u64);
        let mut a = vec![0.0f32; batch * cfg.m * cfg.k];
        let mut b = vec![0.0f32; batch * cfg.k * cfg.n];
        let mut c = vec![0.0f32; batch * cfg.m * cfg.n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        rng.fill_normal(&mut c);
        let native = NativeExec::new(cfg).run(batch, &a, &b, &c);
        let mut artifact = ArtifactExec::new(&mut store, cfg).expect("artifact load");
        let pjrt = artifact.run(batch, &a, &b, &c);
        assert_eq!(native, pjrt, "batch {batch}");
    }
}

#[test]
fn profiling_results_identical_across_backends() {
    let Some(mut store) = store() else { return };
    let cfg = NumericCfg::new("bf16", "f32", 16, 8, 8);
    for op in ProfileOp::ALL {
        for init in [InitKind::LowPrecision, InitKind::Fp32] {
            let n = profile_op(&mut NativeExec::new(cfg), op, init, 500, 7);
            let mut artifact = ArtifactExec::new(&mut store, cfg).expect("artifact load");
            let p = profile_op(&mut artifact, op, init, 500, 7);
            assert_eq!(
                n.mean_abs_err.to_bits(),
                p.mean_abs_err.to_bits(),
                "{op:?}/{init:?}"
            );
        }
    }
}
