//! Integration: tcserved end-to-end over real sockets — boot the server
//! on an ephemeral port, drive it with raw HTTP/1.1 GETs, and verify
//! the content-addressed cache (second request is a hit, concurrent
//! identical requests compute once) plus the error contract (404/400
//! with JSON bodies).

use std::io::{Read, Write};
use std::net::SocketAddr;
use std::net::TcpStream;

use tcbench::server::{Server, ServerConfig};
use tcbench::util::Json;

fn start() -> Server {
    Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 8,
        warm: false,
        disk_cache: None,
        cache_capacity: 64,
        // never attach a disk store to the process-global cell cache
        // inside this test binary (other tests share the process)
        cell_store: None,
        ..ServerConfig::default()
    })
    .expect("tcserved start")
}

/// Unwrap a `tcserved/v1` success envelope into its `data` payload.
fn data(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    assert!(j.get("error").is_none(), "unexpected error envelope: {j}");
    j.get("data").unwrap_or_else(|| panic!("no data in {j}")).clone()
}

/// Unwrap a `tcserved/v1` error envelope into its `error` object.
fn error_of(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    assert!(j.get("data").is_none(), "unexpected success envelope: {j}");
    j.get("error").unwrap_or_else(|| panic!("no error in {j}")).clone()
}

/// One raw HTTP exchange; returns (status, body).
fn request_raw(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send request");
    stream.flush().unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {response:?}"))
        .parse()
        .expect("numeric status");
    let body = response.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn get_raw(addr: SocketAddr, target: &str) -> (u16, String) {
    request_raw(
        addr,
        &format!("GET {target} HTTP/1.1\r\nHost: tcserved\r\nConnection: close\r\n\r\n"),
    )
}

/// GET and parse the JSON body (every tcserved response is JSON).
fn get(addr: SocketAddr, target: &str) -> (u16, Json) {
    let (status, body) = get_raw(addr, target);
    let json = Json::parse(&body)
        .unwrap_or_else(|e| panic!("GET {target}: body is not JSON ({e}): {body:?}"));
    (status, json)
}

#[test]
fn healthz_and_registry_endpoints() {
    let server = start();
    let addr = server.addr();

    let (status, j) = get(addr, "/healthz");
    assert_eq!(status, 200);
    let j = data(&j);
    assert_eq!(j.get_str("status"), Some("ok"));
    assert_eq!(j.get_u64("experiments"), Some(19));

    let (status, j) = get(addr, "/v1/experiments");
    assert_eq!(status, 200);
    let j = data(&j);
    assert_eq!(j.get_u64("count"), Some(19));
    let list = j.get("experiments").unwrap().as_arr().unwrap();
    assert_eq!(list.len(), 19);
    assert!(list.iter().any(|e| e.get_str("id") == Some("t3")));
    assert!(list.iter().all(|e| e.get("cached").and_then(Json::as_bool) == Some(false)));

    let (status, j) = get(addr, "/v1/devices");
    assert_eq!(status, 200);
    let j = data(&j);
    let devices = j.get("devices").unwrap().as_arr().unwrap();
    assert_eq!(devices.len(), 4);
    assert!(devices.iter().any(|d| d.get_str("name") == Some("a100")));

    let (status, j) = get(addr, "/v1/nope");
    assert_eq!(status, 404);
    assert_eq!(error_of(&j).get_str("code"), Some("not_found"));

    server.stop();
}

#[test]
fn second_run_request_is_served_from_cache() {
    let server = start();
    let addr = server.addr();

    // first hit computes t3 (the paper's dense A100 table)
    let (status, j1) = get(addr, "/v1/run/t3");
    assert_eq!(status, 200, "{j1:?}");
    let j1 = data(&j1);
    assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
    assert_eq!(j1.get_str("origin"), Some("computed"));
    let r1 = j1.get("result").unwrap();
    assert_eq!(r1.get_str("id"), Some("t3"));
    assert_eq!(r1.get_str("backend"), Some("native"));
    assert!(r1.get_f64("compute_ms").unwrap() > 0.0);
    let report = r1.get("report").unwrap();
    assert!(report.get_str("text").unwrap().contains("Table 3"));
    assert!(!report.get("tables").unwrap().as_arr().unwrap().is_empty());

    // second hit is served from the content-addressed cache
    let (status, j2) = get(addr, "/v1/run/t3");
    assert_eq!(status, 200);
    let j2 = data(&j2);
    assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true));
    assert_eq!(j2.get_str("origin"), Some("memory"));
    // identical payload — same content address, no recomputation
    assert_eq!(j2.get("result").unwrap().to_string(), r1.to_string());

    // /v1/metrics proves it: one computation, one cache hit
    let (status, m) = get(addr, "/v1/metrics");
    assert_eq!(status, 200);
    let m = data(&m);
    let t3 = m.get("experiments").unwrap().get("t3").unwrap();
    assert_eq!(t3.get_u64("computes"), Some(1), "t3 must have computed exactly once: {m}");
    assert!(m.get("cache").unwrap().get_u64("hits").unwrap() >= 1, "{m}");
    let cached_flag = data(&get(addr, "/v1/experiments").1);
    let t3_entry = cached_flag
        .get("experiments")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|e| e.get_str("id") == Some("t3"))
        .unwrap()
        .clone();
    assert_eq!(t3_entry.get("cached").and_then(Json::as_bool), Some(true));

    server.stop();
}

#[test]
fn concurrent_identical_requests_compute_once() {
    let server = start();
    let addr = server.addr();

    const CLIENTS: usize = 6;
    let origins: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                scope.spawn(move || {
                    let (status, j) = get(addr, "/v1/run/fig7");
                    assert_eq!(status, 200, "{j:?}");
                    let j = data(&j);
                    assert_eq!(j.get("result").unwrap().get_str("id"), Some("fig7"));
                    j.get_str("origin").unwrap().to_string()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert!(origins
        .iter()
        .all(|o| matches!(o.as_str(), "computed" | "coalesced" | "memory")), "{origins:?}");
    assert_eq!(origins.iter().filter(|o| *o == "computed").count(), 1, "{origins:?}");

    // single-flight: six concurrent identical requests, one computation
    let m = data(&get(addr, "/v1/metrics").1);
    let fig7 = m.get("experiments").unwrap().get("fig7").unwrap();
    assert_eq!(fig7.get_u64("computes"), Some(1), "single-flight violated: {m}");
    let cache = m.get("cache").unwrap();
    let served_without_compute =
        cache.get_u64("hits").unwrap() + cache.get_u64("coalesced").unwrap();
    assert_eq!(served_without_compute, (CLIENTS - 1) as u64, "{m}");

    server.stop();
}

#[test]
fn unknown_experiment_is_404_with_json_error() {
    let server = start();
    let addr = server.addr();

    let (status, j) = get(addr, "/v1/run/t99");
    assert_eq!(status, 404);
    let err = error_of(&j);
    assert_eq!(err.get_str("code"), Some("unknown_experiment"));
    assert!(err.get_str("message").unwrap().contains("t99"), "{err}");
    assert_eq!(err.get_u64("status"), Some(404));

    // an unknown experiment never reaches the compute path
    let m = data(&get(addr, "/v1/metrics").1);
    assert!(m.get("experiments").unwrap().get("t99").is_none());

    server.stop();
}

#[test]
fn malformed_requests_are_4xx_with_json_errors() {
    let server = start();
    let addr = server.addr();

    // missing required parameter
    let (status, j) = get(addr, "/v1/sweep");
    assert_eq!(status, 400);
    let err = error_of(&j);
    assert_eq!(err.get_str("code"), Some("invalid_param"));
    assert!(err.get_str("message").unwrap().contains("instr"));

    // unparseable instruction spec
    let (status, _) = get(addr, "/v1/sweep?device=a100&instr=garbage");
    assert_eq!(status, 400);

    // unknown device / unknown backend
    let (status, _) = get(addr, "/v1/sweep?device=h100&instr=bf16,f32,m16n8k16");
    assert_eq!(status, 404);
    let (status, _) = get(addr, "/v1/run/t3?backend=cuda");
    assert_eq!(status, 400);

    // wrong method
    let (status, j) =
        request_raw(addr, "POST /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 405);
    let err = error_of(&Json::parse(&j).unwrap());
    assert_eq!(err.get_str("code"), Some("method_not_allowed"));

    // garbage request line
    let (status, body) = request_raw(addr, "NONSENSE\r\n\r\n");
    assert_eq!(status, 400);
    let err = error_of(&Json::parse(&body).unwrap());
    assert_eq!(err.get_str("code"), Some("malformed_request"));

    server.stop();
}

#[test]
fn sweep_endpoint_end_to_end() {
    let server = start();
    let addr = server.addr();

    // '+'-separated spec exercises percent-decoding of query params
    let (status, j) = get(addr, "/v1/sweep?device=a100&instr=bf16+f32+m16n8k16");
    assert_eq!(status, 200, "{j:?}");
    let j = data(&j);
    let result = j.get("result").unwrap();
    assert_eq!(result.get_str("device"), Some("a100"));
    assert_eq!(result.get("cells").unwrap().as_arr().unwrap().len(), 48);
    let peak = result.get_f64("peak_throughput").unwrap();
    assert!((960.0..1030.0).contains(&peak), "peak {peak}");

    // same coordinates -> same content address -> cache hit
    let (_, j2) = get(addr, "/v1/sweep?device=a100&instr=bf16,f32,m16n8k16");
    assert_eq!(data(&j2).get("cached").and_then(Json::as_bool), Some(true));

    server.stop();
}
