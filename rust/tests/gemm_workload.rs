//! Integration: the GEMM workload family end to end — the plan-backed
//! tables 16/17 pinned against `report::expected` and against the
//! legacy `gemm::table16`/`table17` direct path (the Workload promotion
//! must not change the numbers), plus (CTA warps, stages) sweeps with
//! the shared convergence machinery.

use tcbench::coordinator::run_experiment;
use tcbench::device::a100;
use tcbench::gemm::{self, GemmConfig};
use tcbench::report::expected;
use tcbench::workload::{Plan, SimRunner, Workload};

#[test]
fn table16_report_is_plan_backed_and_pinned() {
    let report = run_experiment("t16", &SimRunner).unwrap();
    // the paper's published cycle counts are in the table
    assert!(report.contains(&expected::TABLE16_BASELINE.to_string()), "{report}");
    assert!(report.contains(&expected::TABLE16_PIPELINE.to_string()), "{report}");
    assert!(report.contains("mma_baseline.cu") && report.contains("mma_pipeline.cu"));

    // the plan-backed cycles equal the legacy direct path exactly
    let d = a100();
    let (base, pipe) = gemm::table16(&d, GemmConfig::default());
    assert!(
        report.contains(&base.total_cycles.to_string()),
        "baseline {} missing:\n{report}",
        base.total_cycles
    );
    assert!(
        report.contains(&pipe.total_cycles.to_string()),
        "pipeline {} missing:\n{report}",
        pipe.total_cycles
    );
    let speedup = base.total_cycles as f64 / pipe.total_cycles as f64;
    assert!((1.4..3.0).contains(&speedup), "async speedup {speedup}");
}

#[test]
fn table17_report_is_plan_backed_and_pinned() {
    let report = run_experiment("t17", &SimRunner).unwrap();
    assert!(report.contains(&expected::TABLE16_BASELINE.to_string()), "{report}");
    assert!(report.contains(&expected::TABLE17_PERMUTED.to_string()), "{report}");
    assert!(report.contains("mma_baseline.cu") && report.contains("mma_permuted.cu"));

    let d = a100();
    let (base, perm) = gemm::table17(&d, GemmConfig::default());
    assert!(
        report.contains(&base.total_cycles.to_string()),
        "baseline {} missing:\n{report}",
        base.total_cycles
    );
    assert!(
        report.contains(&perm.total_cycles.to_string()),
        "permuted {} missing:\n{report}",
        perm.total_cycles
    );
    let speedup = base.total_cycles as f64 / perm.total_cycles as f64;
    assert!((1.8..4.5).contains(&speedup), "permuted speedup {speedup}");
}

#[test]
fn gemm_sweep_covers_tile_legal_axes_with_convergence() {
    // the `repro sweep --instr "gemm ..."` shape: completion + full
    // sweep through the one plan path, at a fast 256^3 problem
    let w = Workload::parse_spec("gemm pipeline bf16 f32 256 128x128x32").unwrap();
    let plan = Plan::new(w)
        .device("a100")
        .completion_latency()
        .sweep()
        .compile()
        .unwrap();
    let r = plan.run(&SimRunner, 4).unwrap();
    assert!(r.completion().unwrap() > 0.0);
    let sweep = r.sweep().unwrap();
    // warp axis drops the non-power-of-two counts; the ilp axis carries
    // the cp.async stage depths
    assert_eq!(sweep.warps_axis, vec![1, 2, 4, 8, 16, 32]);
    assert_eq!(sweep.ilp_axis, vec![1, 2, 3, 4]);
    assert_eq!(sweep.cells.len(), 24);
    // the compute scales with warps: the paper's 8-warp CTA beats 1 warp
    let t1 = sweep.cell(1, 2).unwrap().throughput;
    let t8 = sweep.cell(8, 2).unwrap().throughput;
    assert!(t8 > t1, "t1={t1} t8={t8}");
    // double buffering beats the synchronous single stage at 8 warps
    let s1 = sweep.cell(8, 1).unwrap().latency;
    let s2 = sweep.cell(8, 2).unwrap().latency;
    assert!(s2 < s1, "stages=1 {s1} vs stages=2 {s2}");
    // the shared convergence machinery summarizes the default 4/8 warps
    assert!(r.convergence(4).is_some());
    assert!(r.convergence(8).is_some());
}
