//! Integration: the observability layer end to end — stall attribution
//! must never perturb timing (profiling off and on produce bit-identical
//! results across every workload family), Counting profiles must account
//! every warp-cycle exactly once, the Chrome trace export must be valid
//! and per-warp monotonic, and the Prometheus `/metrics` scrape must
//! agree with the `/v1/metrics` JSON counters under mixed traffic.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use tcbench::device;
use tcbench::report::{render_bench, trace_to_json};
use tcbench::server::{Server, ServerConfig};
use tcbench::sim::{ProfileMode, Profiler, STALL_CATEGORIES};
use tcbench::util::Json;
use tcbench::workload::{ExecPoint, Plan, SimRunner, Workload};

/// One spec per workload family (the numeric family runs no cycle
/// simulation and must simply pass through unprofiled).
const FAMILIES: [&str; 7] = [
    "mma bf16 f32 m16n8k16",
    "mma.sp fp16 f32 m16n8k32",
    "ldmatrix x4",
    "ld.shared u32 4",
    "wmma fp16 f32 m16n16k16",
    "gemm pipeline bf16 f32 256 128x128x32",
    "numeric profile bf16 f32 acc fp32",
];

fn compile(spec: &str) -> tcbench::workload::BenchPlan {
    let workload = Workload::parse_spec(spec).unwrap();
    let mut plan = Plan::new(workload).device("a100");
    if matches!(workload, Workload::Numeric(_)) {
        plan = plan.point(1, 1);
    } else {
        plan = plan.point(8, 2).completion_latency();
    }
    plan.compile().unwrap_or_else(|e| panic!("{spec}: {e}"))
}

// ------------------------------------------------- timing invariance

#[test]
fn profiling_off_and_on_agree_bit_identically_across_families() {
    for spec in FAMILIES {
        let plan = compile(spec);
        let off = plan.run(&SimRunner, 2).unwrap_or_else(|e| panic!("{spec}: {e}"));
        let on = plan
            .run_profiled(&SimRunner, 2, ProfileMode::Counting)
            .unwrap_or_else(|e| panic!("{spec}: {e}"));

        // every unit output — cycles, iter marks, throughputs — must be
        // bit-identical; Debug covers every field of every Measurement
        assert_eq!(
            format!("{:?}", off.units),
            format!("{:?}", on.units),
            "{spec}: profiling perturbed the timing results"
        );
        assert_eq!(render_bench(&off), render_bench(&on), "{spec}");

        // the off run carries no profiles; the on run profiles exactly
        // the units that ran a cycle simulation
        assert!(off.unit_profiles.iter().all(Option::is_none), "{spec}");
        assert!(off.stall_profile().is_none(), "{spec}");
        let numeric = matches!(off.workload, Workload::Numeric(_));
        if numeric {
            assert!(on.stall_profile().is_none(), "{spec}: numeric probes have no cycles");
        } else {
            assert!(on.stall_profile().is_some(), "{spec}: no stall profile attached");
        }
    }
}

// ---------------------------------------------- exhaustive accounting

#[test]
fn stall_categories_account_every_warp_cycle() {
    // a known small program, profiled directly: 2 warps, no ILP
    let dev = device::by_name("a100").unwrap();
    let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
    let mut prof = Profiler::counting();
    let m = w.measure_profiled(&dev, ExecPoint::new(2, 1), &mut prof);
    assert!(m.latency > 0.0);
    let p = prof.take_profile().unwrap();
    assert_eq!(p.runs, 1);
    assert_eq!(p.warps, 2);
    assert_eq!(p.categories().len(), STALL_CATEGORIES.len());
    // the invariant: every warp-cycle lands in exactly one category
    assert_eq!(p.warp_cycles, p.warps * p.cycles);
    assert_eq!(p.total(), p.warp_cycles, "{p:?}");
    assert!(p.issued > 0, "{p:?}");
    let frac_sum: f64 = p.fractions().iter().map(|(_, f)| f).sum();
    assert!((frac_sum - 1.0).abs() < 1e-9, "fractions sum to {frac_sum}");

    // and through the plan path: each profiled unit is one run, so the
    // same exhaustiveness holds per unit
    let plan = compile("ld.shared u32 4");
    let result = plan.run_profiled(&SimRunner, 2, ProfileMode::Counting).unwrap();
    let mut seen = 0;
    for i in 0..result.unit_profiles.len() {
        let Some(p) = result.unit_stall_profile(i) else { continue };
        seen += 1;
        assert_eq!(p.runs, 1, "{p:?}");
        assert_eq!(p.warp_cycles, p.warps * p.cycles, "{p:?}");
        assert_eq!(p.total(), p.warp_cycles, "{p:?}");
    }
    assert!(seen >= 2, "point + completion units must both be profiled");
}

// ------------------------------------------------------- trace export

#[test]
fn trace_export_is_valid_and_monotonic_per_warp() {
    let dev = device::by_name("a100").unwrap();
    let w = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
    let (m, p) =
        w.measure_cached_profiled(&dev, ExecPoint::new(2, 2), "sim", ProfileMode::Tracing);
    assert!(m.latency > 0.0);
    let p = p.expect("tracing must yield a profile");
    assert!(!p.events.is_empty());
    assert_eq!(p.events_dropped, 0);

    // per warp, issue timestamps strictly advance (one issue per cycle)
    let mut last: BTreeMap<usize, u64> = BTreeMap::new();
    for e in &p.events {
        if let Some(prev) = last.get(&e.warp) {
            assert!(e.ts > *prev, "warp {} regressed: {} after {}", e.warp, e.ts, prev);
        }
        last.insert(e.warp, e.ts);
    }
    assert_eq!(last.len(), 2, "both warps must have tracks");

    // the export round-trips as JSON with one metadata event per warp
    // and one complete event per recorded issue
    let j = Json::parse(&trace_to_json(&p).to_string()).expect("trace JSON parses");
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    let metas: Vec<_> = events.iter().filter(|e| e.get_str("ph") == Some("M")).collect();
    let complete: Vec<_> = events.iter().filter(|e| e.get_str("ph") == Some("X")).collect();
    assert_eq!(metas.len(), 2);
    assert_eq!(complete.len(), p.events.len());
    for e in complete {
        assert!(e.get_str("name").is_some());
        assert!(e.get_u64("ts").is_some());
        assert!(e.get_u64("dur").unwrap() >= 1, "Perfetto needs nonzero durations");
    }
}

// ----------------------------------------------- /metrics vs JSON

fn start() -> Server {
    Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        warm: false,
        disk_cache: None,
        cache_capacity: 64,
        // keep the process-global cell cache memory-only in this binary
        cell_store: None,
        ..ServerConfig::default()
    })
    .expect("tcserved start")
}

/// Unwrap a `tcserved/v1` success envelope into its `data` payload.
fn data(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    j.get("data").unwrap_or_else(|| panic!("no data in {j}")).clone()
}

/// One raw HTTP exchange; returns (status, headers, body).
fn request_raw(addr: SocketAddr, request: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send request");
    stream.flush().unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {response:?}"))
        .parse()
        .expect("numeric status");
    let (head, body) = response
        .split_once("\r\n\r\n")
        .map(|(h, b)| (h.to_string(), b.to_string()))
        .unwrap_or_default();
    (status, head, body)
}

fn get(addr: SocketAddr, target: &str) -> (u16, Json) {
    let (status, _, body) = request_raw(
        addr,
        &format!("GET {target} HTTP/1.1\r\nHost: tcserved\r\nConnection: close\r\n\r\n"),
    );
    (status, Json::parse(&body).expect("JSON body"))
}

/// The value of one Prometheus series, matched on its full
/// `name{labels}` prefix.
fn prom_value(text: &str, series: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(series).and_then(|rest| rest.strip_prefix(' ')))
        .unwrap_or_else(|| panic!("no series {series:?} in scrape:\n{text}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("series {series:?}: bad value ({e})"))
}

#[test]
fn prometheus_scrape_agrees_with_json_metrics() {
    let server = start();
    let addr = server.addr();

    // mixed traffic: one POSTed plan, a timing sweep (twice — the
    // second is a result-cache hit), and a numeric sweep
    let plan_body = r#"{"workload":"mma bf16 f32 m16n8k16","device":"a100",
                       "points":[[4,2]],"completion_latency":true,"backend":"native"}"#;
    let (status, _, _) = request_raw(
        addr,
        &format!(
            "POST /v1/plan HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{plan_body}",
            plan_body.len()
        ),
    );
    assert_eq!(status, 200);
    for _ in 0..2 {
        let (status, j) = get(addr, "/v1/sweep?device=a100&instr=ldmatrix+x4");
        assert_eq!(status, 200, "{j:?}");
    }
    let (status, j) = get(addr, "/v1/sweep?device=a100&instr=numeric+chain+tf32+f32+6");
    assert_eq!(status, 200, "{j:?}");

    let (status, json) = get(addr, "/v1/metrics");
    assert_eq!(status, 200);
    let json = data(&json);
    let (status, head, text) = request_raw(
        addr,
        "GET /metrics HTTP/1.1\r\nHost: tcserved\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 200);
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");

    // -- the scrape is well-formed exposition text ---------------------
    let mut help_seen = BTreeSet::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(rest.starts_with("HELP ") || rest.starts_with("TYPE "), "{line}");
            if let Some(h) = rest.strip_prefix("HELP ") {
                let name = h.split_whitespace().next().unwrap();
                assert!(help_seen.insert(name.to_string()), "duplicate HELP for {name}");
            }
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("{line}"));
        assert!(value.parse::<f64>().is_ok(), "unparseable value: {line}");
        assert!(series.starts_with("tcserved_"), "{line}");
        let name_end = series.find('{').unwrap_or(series.len());
        assert!(
            series[..name_end].chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "{line}"
        );
        if let Some(open) = series.find('{') {
            assert!(series.ends_with('}'), "{line}");
            for pair in series[open + 1..series.len() - 1].split(',') {
                let (_, v) = pair.split_once('=').unwrap_or_else(|| panic!("{line}"));
                assert!(v.starts_with('"') && v.ends_with('"'), "{line}");
            }
        }
    }

    // -- and it agrees with the JSON counters --------------------------
    // the JSON snapshot was taken while serving its own (already
    // counted) request, so the later scrape sees exactly one more
    let json_requests = json.get_f64("requests_total").unwrap();
    assert_eq!(prom_value(&text, "tcserved_requests_total"), json_requests + 1.0);

    let by_endpoint = json.get("by_endpoint").unwrap();
    for endpoint in ["plan", "sweep", "metrics"] {
        let series = format!("tcserved_endpoint_requests_total{{endpoint=\"{endpoint}\"}}");
        assert_eq!(
            prom_value(&text, &series),
            by_endpoint.get_f64(endpoint).unwrap(),
            "{endpoint}"
        );
    }

    let cache = json.get("cache").unwrap();
    assert!(cache.get_f64("hits").unwrap() >= 1.0, "second sweep must hit: {cache}");
    for (series, key) in [
        ("tcserved_result_cache_hits_total", "hits"),
        ("tcserved_result_cache_misses_total", "misses"),
        ("tcserved_result_cache_entries", "entries"),
    ] {
        assert_eq!(prom_value(&text, series), cache.get_f64(key).unwrap(), "{key}");
    }

    // latency histograms: the sweep endpoint saw exactly 3 requests,
    // and the +Inf bucket of a histogram always equals its count
    let sweep_latency = json.get("latency_us").unwrap().get("sweep").unwrap();
    assert_eq!(sweep_latency.get_f64("count"), Some(3.0), "{sweep_latency}");
    assert_eq!(
        prom_value(&text, "tcserved_request_duration_us_count{endpoint=\"sweep\"}"),
        3.0
    );
    assert_eq!(
        prom_value(&text, "tcserved_request_duration_us_bucket{endpoint=\"sweep\",le=\"+Inf\"}"),
        3.0
    );

    // compute phases flowed into both views (the metrics endpoints
    // record none of these phases, so the two views agree exactly)
    let phases = json.get("phases_us").unwrap();
    for phase in ["cache_lookup", "simulate", "render"] {
        let count = phases
            .get(phase)
            .unwrap_or_else(|| panic!("phase {phase} missing: {phases}"))
            .get_f64("count")
            .unwrap();
        assert!(count >= 1.0, "{phase}");
        let series = format!("tcserved_phase_duration_us_count{{phase=\"{phase}\"}}");
        assert_eq!(prom_value(&text, &series), count, "{phase}");
    }

    server.stop();
}
