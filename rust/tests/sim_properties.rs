//! Property tests over tcsim (hand-rolled generator — proptest is not
//! vendored): the cycle simulator must agree with the closed-form
//! analytic model on the microbenchmark family, and obey structural
//! invariants (work conservation, monotonicity, sub-core isolation).

use tcbench::device::{self, Device, FpuFallback};
use tcbench::isa::{LdMatrixNum, MmaInstr};
use tcbench::microbench::{measure_ldmatrix, measure_mma};
use tcbench::sim::{predict_ldmatrix, predict_mma};
use tcbench::util::Prng;

fn mma_cases(device: &Device, rng: &mut Prng, n: usize) -> Vec<(MmaInstr, u32, u32)> {
    let instrs: Vec<MmaInstr> = device
        .mma_timings
        .iter()
        .filter(|(_, t)| t.fpu_fallback == FpuFallback::No)
        .map(|(i, _)| *i)
        .collect();
    let warps_axis = [1u32, 2, 4, 6, 8, 12, 16, 32];
    (0..n)
        .map(|_| {
            let i = instrs[rng.below(instrs.len() as u64) as usize];
            let w = warps_axis[rng.below(warps_axis.len() as u64) as usize];
            let ilp = 1 + rng.below(6) as u32;
            (i, w, ilp)
        })
        .collect()
}

/// Simulated latency within 15% (or 3 cycles) of the analytic model for
/// randomly drawn configurations on every device.
#[test]
fn sim_agrees_with_analytic_model() {
    let mut rng = Prng::new(2024);
    for dev in device::registry() {
        for (instr, warps, ilp) in mma_cases(&dev, &mut rng, 60) {
            let sim = measure_mma(&dev, &instr, warps, ilp);
            let ana = predict_mma(&dev, &instr, warps, ilp).unwrap();
            let abs = (sim.latency - ana.latency).abs();
            let rel = abs / ana.latency;
            assert!(
                rel < 0.15 || abs <= 3.0,
                "{}: {instr} w={warps} ilp={ilp}: sim {} vs analytic {}",
                dev.name,
                sim.latency,
                ana.latency
            );
        }
    }
}

/// Throughput never exceeds the device's theoretical peak (plus a small
/// integer-rounding allowance).
#[test]
fn throughput_never_exceeds_peak() {
    let mut rng = Prng::new(7);
    for dev in device::registry() {
        for (instr, warps, ilp) in mma_cases(&dev, &mut rng, 60) {
            let sim = measure_mma(&dev, &instr, warps, ilp);
            // The calibrated ii defines the practically reachable peak
            // (anomalous instructions cannot reach the vendor number).
            let ii = dev.timing(&instr).unwrap().ii as f64;
            let reachable = dev.subcores as f64 * instr.fmas() as f64 / ii;
            assert!(
                sim.throughput <= reachable * 1.05,
                "{}: {instr} w={warps} ilp={ilp}: {} > {reachable}",
                dev.name,
                sim.throughput
            );
        }
    }
}

/// More warps at fixed ILP never *reduces* total throughput by more than
/// the 6-warp-style imbalance bound (worst sub-core load ratio).
#[test]
fn warp_scaling_monotone_up_to_imbalance() {
    let mut rng = Prng::new(99);
    let dev = device::a100();
    for (instr, _, ilp) in mma_cases(&dev, &mut rng, 25) {
        let mut last = 0.0;
        for warps in [1u32, 2, 4, 8, 16] {
            let thr = measure_mma(&dev, &instr, warps, ilp).throughput;
            assert!(
                thr >= last * 0.99,
                "{instr} ilp={ilp}: thr dropped {last} -> {thr} at {warps} warps"
            );
            last = thr;
        }
    }
}

/// Latency is non-decreasing in ILP at fixed #warps (adding independent
/// chains can only lengthen an iteration).
#[test]
fn latency_monotone_in_ilp() {
    let dev = device::a100();
    let mut rng = Prng::new(5);
    for (instr, warps, _) in mma_cases(&dev, &mut rng, 25) {
        let mut last = 0.0;
        for ilp in 1..=6 {
            let lat = measure_mma(&dev, &instr, warps, ilp).latency;
            assert!(
                lat + 1e-9 >= last,
                "{instr} w={warps}: latency dropped {last} -> {lat} at ILP {ilp}"
            );
            last = lat;
        }
    }
}

/// Sub-core isolation: K warps spread over K sub-cores must scale
/// throughput K-fold vs one warp (the paper's finding 3).
#[test]
fn subcore_isolation_scaling() {
    let dev = device::a100();
    for instr in [
        MmaInstr::dense(tcbench::isa::AbType::Bf16, tcbench::isa::CdType::Fp32, tcbench::isa::shapes::M16N8K16),
        MmaInstr::sp(tcbench::isa::AbType::Bf16, tcbench::isa::CdType::Fp32, tcbench::isa::shapes::M16N8K32),
    ] {
        let t1 = measure_mma(&dev, &instr, 1, 2).throughput;
        for warps in [2u32, 4] {
            let t = measure_mma(&dev, &instr, warps, 2).throughput;
            let ratio = t / t1;
            assert!(
                (ratio - warps as f64).abs() < 0.25,
                "{instr}: {warps}-warp scaling {ratio}"
            );
        }
    }
}

/// ldmatrix: simulated latency within 15% of the analytic LSU model.
#[test]
fn ldmatrix_sim_agrees_with_analytic() {
    let dev = device::a100();
    let mut rng = Prng::new(3);
    for _ in 0..40 {
        let num = [LdMatrixNum::X1, LdMatrixNum::X2, LdMatrixNum::X4]
            [rng.below(3) as usize];
        let warps = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
        let ilp = 1 + rng.below(5) as u32;
        let sim = measure_ldmatrix(&dev, num, warps, ilp);
        let ana = predict_ldmatrix(&dev, num, warps, ilp).unwrap();
        let rel = (sim.latency - ana.latency).abs() / ana.latency;
        assert!(
            rel < 0.18 || (sim.latency - ana.latency).abs() <= 4.0,
            "{num} w={warps} ilp={ilp}: sim {} vs analytic {}",
            sim.latency,
            ana.latency
        );
    }
}

/// Shared-memory bandwidth is conserved: bytes/clk never exceeds the
/// 128 B/clk fabric bound.
#[test]
fn smem_bandwidth_bound() {
    let dev = device::a100();
    let mut rng = Prng::new(17);
    for _ in 0..40 {
        let num = [LdMatrixNum::X1, LdMatrixNum::X2, LdMatrixNum::X4]
            [rng.below(3) as usize];
        let warps = 1 + rng.below(32) as u32;
        let ilp = 1 + rng.below(6) as u32;
        let m = measure_ldmatrix(&dev, num, warps, ilp);
        assert!(
            m.throughput <= dev.smem_peak_bytes_per_clk() as f64 * 1.02,
            "{num} w={warps} ilp={ilp}: {}",
            m.throughput
        );
    }
}
