//! Integration: horizontal serving — two `repro serve` processes
//! sharing one `--cell-store` directory. Replica A simulates a sweep
//! and persists every cell; replica B (a fresh process with its own
//! result cache) answers the identical sweep from cell-store hits,
//! bit-identically. Plus a `loadgen` smoke test against an in-process
//! server.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use tcbench::loadgen::{self, http_request, LoadgenConfig};
use tcbench::server::{Server, ServerConfig};
use tcbench::util::Json;

/// A per-test scratch tree under the target-adjacent temp dir.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tcbench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

struct Replica {
    child: Child,
    addr: String,
}

impl Replica {
    /// Spawn `repro serve --addr 127.0.0.1:0` with its own working
    /// directory (so per-replica result caches stay private) and a
    /// shared cell-store directory; parse the bound address from the
    /// startup banner on stderr.
    fn spawn(cwd: &Path, cell_store: &Path) -> Replica {
        std::fs::create_dir_all(cwd).expect("replica cwd");
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args([
                "serve",
                "--addr",
                "127.0.0.1:0",
                "--threads",
                "2",
                "--cell-store",
                cell_store.to_str().unwrap(),
            ])
            .current_dir(cwd)
            .stdout(Stdio::null())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn repro serve");
        let stderr = child.stderr.take().expect("stderr piped");
        let mut banner = String::new();
        let mut addr = None;
        for line in BufReader::new(stderr).lines() {
            let line = line.expect("read server stderr");
            banner.push_str(&line);
            banner.push('\n');
            if let Some(rest) = line.split("listening on http://").nth(1) {
                let end = rest.find(|c: char| c.is_whitespace()).unwrap_or(rest.len());
                addr = Some(rest[..end].to_string());
                break;
            }
            if banner.len() > 16_384 {
                break;
            }
        }
        let addr = addr.unwrap_or_else(|| {
            let _ = child.kill();
            panic!("no listening banner from repro serve; stderr so far:\n{banner}")
        });
        Replica { child, addr }
    }

    fn post(&self, path: &str, body: &str) -> Json {
        let (status, response) =
            http_request(&self.addr, "POST", path, body).expect("http round trip");
        let j = Json::parse(&response).expect("JSON body");
        assert_eq!(status, 200, "{path}: {j}");
        assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
        j.get("data").unwrap_or_else(|| panic!("no data in {j}")).clone()
    }

    fn metrics(&self) -> Json {
        let (status, response) =
            http_request(&self.addr, "GET", "/v1/metrics", "").expect("metrics scrape");
        assert_eq!(status, 200);
        Json::parse(&response).expect("JSON").get("data").expect("data").clone()
    }
}

impl Drop for Replica {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// The (latency, throughput) bit patterns of every cell in a sweep
/// response — the payload that must survive the store round trip.
fn cell_bits(result: &Json) -> Vec<(u32, u32, u64, u64)> {
    result
        .get("cells")
        .expect("cells")
        .as_arr()
        .expect("cells array")
        .iter()
        .map(|c| {
            (
                c.get_u64("warps").unwrap() as u32,
                c.get_u64("ilp").unwrap() as u32,
                c.get_f64("latency").unwrap().to_bits(),
                c.get_f64("throughput").unwrap().to_bits(),
            )
        })
        .collect()
}

#[test]
fn two_replicas_share_one_cell_store_bit_identically() {
    let base = scratch("replica_store");
    let cells = base.join("cells");
    let sweep_body = r#"{"instr":"ldmatrix x2","device":"a100"}"#;

    // replica A simulates the sweep and persists every cell
    let bits_a;
    {
        let a = Replica::spawn(&base.join("a"), &cells);
        let result = a.post("/v1/sweep", sweep_body);
        bits_a = cell_bits(result.get("result").expect("result"));
        assert!(!bits_a.is_empty());
        let store = a.metrics().get("cell_store").expect("cell_store section").clone();
        assert_eq!(store.get("enabled").and_then(Json::as_bool), Some(true), "{store}");
        assert!(store.get_u64("writes").unwrap() >= bits_a.len() as u64, "{store}");
        // replica A stops here (Drop kills the process): the store on
        // disk is all that survives into the next replica
    }
    let persisted = std::fs::read_dir(&cells).expect("store dir").count();
    let want = bits_a.len();
    assert!(persisted >= want, "expected >= {want} cell files, found {persisted}");

    // replica B: fresh process, empty result cache, same store — the
    // identical sweep must be served from cell-store hits, bit-identically
    let b = Replica::spawn(&base.join("b"), &cells);
    let result = b.post("/v1/sweep", sweep_body);
    let bits_b = cell_bits(result.get("result").expect("result"));
    assert_eq!(bits_a, bits_b, "replica B's cells are not bit-identical to replica A's");

    let m = b.metrics();
    let store = m.get("cell_store").expect("cell_store section");
    assert!(
        store.get_u64("hits").unwrap() >= bits_a.len() as u64,
        "replica B must serve the sweep from the shared store: {m}"
    );
    assert_eq!(store.get_u64("writes"), Some(0), "nothing new to persist: {m}");
    drop(b);

    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn corrupt_cell_files_degrade_to_misses_not_errors() {
    let base = scratch("replica_store_corrupt");
    let cells = base.join("cells");
    let sweep_body = r#"{"instr":"ld.shared u32 4","device":"a100"}"#;

    // seed the store, then corrupt every persisted cell file
    {
        let a = Replica::spawn(&base.join("a"), &cells);
        a.post("/v1/sweep", sweep_body);
    }
    let mut clobbered = 0;
    for entry in std::fs::read_dir(&cells).expect("store dir") {
        let path = entry.expect("entry").path();
        std::fs::write(&path, "{definitely not a cell").expect("clobber");
        clobbered += 1;
    }
    assert!(clobbered > 0);

    // a fresh replica must treat every corrupt file as a miss,
    // recompute, and answer 200
    let b = Replica::spawn(&base.join("b"), &cells);
    let result = b.post("/v1/sweep", sweep_body);
    assert!(result.get("result").is_some(), "{result}");
    let m = b.metrics();
    let store = m.get("cell_store").expect("cell_store section");
    assert!(store.get_u64("corrupt").unwrap() > 0, "corruption must be counted: {m}");
    assert_eq!(store.get_u64("hits"), Some(0), "{m}");
    drop(b);

    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn loadgen_smoke_reports_latency_and_hit_rates() {
    // in-process server; the cell store stays detached in this test
    // binary (the cell cache is a process-wide singleton)
    let server = Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        warm: false,
        disk_cache: None,
        cache_capacity: 64,
        cell_store: None,
        ..ServerConfig::default()
    })
    .expect("tcserved start");

    let cfg = LoadgenConfig {
        addr: server.addr().to_string(),
        mix: loadgen::parse_mix("plan").unwrap(),
        concurrency: 2,
        duration_secs: 1.0,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert!(report.requests > 0, "no traffic generated");
    let accounted = report.ok
        + report.retried_ok
        + report.rejected
        + report.gave_up
        + report.http_errors
        + report.transport_errors;
    assert_eq!(accounted, report.requests);
    assert!(report.ok > 0, "{report:?}");
    assert_eq!(report.transport_errors, 0, "{report:?}");
    assert!(report.p99_us() >= report.p50_us(), "{report:?}");

    let j = report.to_json();
    assert_eq!(j.get_str("schema"), Some("tcbench/loadgen/v1"));
    assert!(j.get("latency_us").unwrap().get_u64("p50").is_some(), "{j}");
    assert!(j.get("server_metrics").is_some(), "metrics scrape missing: {j}");
    // the plan mix repeats a tiny template pool, so the warmed result
    // cache must be serving a measurable share
    assert!(report.result_cache_hit_rate().unwrap_or(0.0) > 0.0, "{j}");

    let text = report.render();
    assert!(text.contains("p50"), "{text}");
    assert!(text.contains("p99"), "{text}");

    server.stop();
}
