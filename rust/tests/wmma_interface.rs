//! Integration: the legacy `wmma` interface model (paper §2.2, Fig. 2/3).
//!
//! The paper's guidance is to program Tensor Cores through the new
//! `mma` interface: a legacy `wmma.mma.m16n16k16` is compiled into two
//! new-style `m16n8k16` HMMAs (Fig. 3), so its *compute* throughput can
//! only match — never beat — the directly-programmed mma sequence,
//! while its chained issue costs extra single-warp latency (and its
//! `wmma.load` forfeits `ldmatrix`'s conflict-avoiding layouts, which
//! this simulator scores separately in §7).

use tcbench::device::a100;
use tcbench::isa::shapes::M16N8K16;
use tcbench::isa::{AbType, CdType, MmaInstr, MmaShape};
use tcbench::microbench::wmma::{
    measure_wmma, wmma_program, wmma_vs_mma, WmmaShape, WMMA_M16N16K16,
};
use tcbench::microbench::{measure_mma, ITERS};

#[test]
fn m16n16k16_lowers_to_exactly_two_m16n8k16_hmmas() {
    let parts = WMMA_M16N16K16.compiled_mmas(AbType::Fp16, CdType::Fp32);
    assert_eq!(parts.len(), 2, "Fig. 3: fragments along n into m16n8 pieces");
    for p in &parts {
        assert_eq!(p.shape, M16N8K16);
        assert_eq!(p.ab, AbType::Fp16);
        assert_eq!(p.cd, CdType::Fp32);
        assert!(!p.sparse);
    }
    // FMA totals match: 2 x (16*8*16) == 16*16*16
    let piece_fmas: u64 = parts.iter().map(MmaInstr::fmas).sum();
    assert_eq!(piece_fmas, WMMA_M16N16K16.fmas());
    assert_eq!(WMMA_M16N16K16.fmas(), 4096);
}

#[test]
fn lowering_scales_with_n_and_keeps_fma_totals() {
    for n in [8u32, 16, 32] {
        let shape = WmmaShape { m: 16, n, k: 16 };
        let parts = shape.compiled_mmas(AbType::Bf16, CdType::Fp32);
        assert_eq!(parts.len(), (n / 8) as usize);
        assert_eq!(parts.iter().map(MmaInstr::fmas).sum::<u64>(), shape.fmas());
    }
}

#[test]
fn compiled_program_accounts_every_fma() {
    let d = a100();
    for ilp in [1u32, 2, 3] {
        let p = wmma_program(&d, WMMA_M16N16K16, AbType::Fp16, CdType::Fp32, ilp, ITERS);
        assert_eq!(
            p.fmas_per_iteration(),
            WMMA_M16N16K16.fmas() * ilp as u64,
            "ilp {ilp}"
        );
    }
}

#[test]
fn wmma_never_beats_the_direct_mma_sequence() {
    // §2.2/Fig. 3: at the same FMA volume the wmma interface is at best
    // equal to the new mma interface — the gap has one sign only.
    let d = a100();
    let (wmma, mma) = wmma_vs_mma(&d, AbType::Fp16, CdType::Fp32);
    assert!(
        wmma.throughput <= mma.throughput * 1.005,
        "wmma {wmma:?} must not outperform mma {mma:?}"
    );
    // and both are in the saturated regime of Table 3 (~1000 FMA/clk/SM)
    assert!((900.0..1030.0).contains(&mma.throughput), "{mma:?}");
    assert!(wmma.throughput > 850.0, "compute path itself is not the loss: {wmma:?}");
}

#[test]
fn wmma_costs_extra_single_warp_latency() {
    // One wmma issues two chained HMMAs: strictly slower per iteration
    // than a single piece at one warp, but well under 2x (the pieces
    // are independent of each other).
    let d = a100();
    let w = measure_wmma(&d, WMMA_M16N16K16, AbType::Fp16, CdType::Fp32, 1, 1);
    let piece = MmaInstr::dense(AbType::Fp16, CdType::Fp32, MmaShape::new(16, 8, 16));
    let m = measure_mma(&d, &piece, 1, 1);
    assert!(w.latency > m.latency, "wmma {w:?} vs mma {m:?}");
    assert!(w.latency < 2.0 * m.latency, "wmma {w:?} vs mma {m:?}");
}
