//! Integration: the tclint static verifier end-to-end.
//!
//! Three contracts are pinned here: (1) every standard workload family
//! lints clean over its full sweep grid — the builders this repo ships
//! never produce a diagnostic; (2) every rule in the catalog has a
//! minimal program that triggers exactly it, so the rule ids are stable
//! API; (3) `POST /v1/lint` serves the diagnostics over a real socket,
//! answering 400 when an Error-severity rule fires.

use std::collections::BTreeSet;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use tcbench::analysis::{verify, Diagnostic, Rule};
use tcbench::device;
use tcbench::server::{Server, ServerConfig};
#[cfg(debug_assertions)]
use tcbench::sim::SmSim;
use tcbench::sim::{Op, ProgramBuilder, WarpProgram};
use tcbench::util::Json;
use tcbench::workload::{Plan, Workload};

// --------------------------------------------------- clean-by-construction

/// One spec per workload family (the paper's five instruction families,
/// the Appendix-A gemm pipeline, and a §8 numeric probe).
const FAMILY_SPECS: &[&str] = &[
    "mma bf16 f32 m16n8k16",
    "mma.sp bf16 f32 m16n8k32",
    "ldmatrix x4",
    "ld.shared u32 4",
    "wmma fp16 f32 m16n16k16",
    "gemm pipeline bf16 f32 256 128x128x32",
    "numeric profile fp16 f32 mul low",
];

#[test]
fn every_workload_family_lints_clean_across_its_sweep_grid() {
    for spec in FAMILY_SPECS {
        let workload = Workload::parse_spec(spec).unwrap();
        let mut plan = Plan::new(workload).sweep();
        if !matches!(workload, Workload::Numeric(_)) {
            plan = plan.completion_latency();
        }
        let bench = plan.compile().unwrap_or_else(|e| panic!("{spec}: {e}"));
        let records = bench.lint();
        assert!(
            records.is_empty(),
            "{spec} must lint clean over its sweep grid, got: {records:?}"
        );
    }
}

// -------------------------------------------------------- rule triggering

fn diags(programs: Vec<WarpProgram>) -> Vec<Diagnostic> {
    let programs: Vec<_> = programs.into_iter().map(Arc::new).collect();
    verify(&programs, &device::a100())
}

fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule.id()).collect()
}

/// Build the minimal program(s) that trigger exactly one rule, keyed by
/// the rule it targets. Each returned launch fires only that rule.
fn broken_launch(rule: Rule) -> Vec<WarpProgram> {
    let cap = device::a100().smem_bytes_per_sm as u64;
    let mut b = ProgramBuilder::new();
    match rule {
        Rule::UndefinedRead => {
            // accumulator chain without init_reg seeding
            let d = b.alloc_reg();
            b.mma(8, 24, 2048, d, vec![d]);
        }
        Rule::DeadWrite => {
            let d = b.alloc_reg();
            b.smem_load(4, 512, d);
            b.smem_load(4, 512, d); // overwrites the first load unread
            b.mma(8, 24, 2048, d, vec![d]);
        }
        Rule::WaitBeforeCommit => {
            b.push(Op::CpAsyncWait { max_pending: 0 }, None, vec![]);
        }
        Rule::EmptyCommit => {
            b.push(Op::CpAsyncCommit, None, vec![]);
        }
        Rule::WaitNoop => {
            b.push(Op::CpAsync { bytes: 512 }, None, vec![]);
            b.push(Op::CpAsyncCommit, None, vec![]);
            // only one group was ever committed; max_pending=1 never blocks
            b.push(Op::CpAsyncWait { max_pending: 1 }, None, vec![]);
        }
        Rule::Uncommitted => {
            b.push(Op::CpAsync { bytes: 512 }, None, vec![]);
        }
        Rule::BarrierMismatch => {
            b.push(Op::BarSync, None, vec![]);
            let with_bar = b.build();
            let without_bar = ProgramBuilder::new().build();
            return vec![with_bar, without_bar];
        }
        Rule::NonuniformBody => {
            let d = b.init_reg();
            b.mma(8, 24, 2048, d, vec![d]);
            b.iter_mark();
            b.mma(8, 24, 2048, d, vec![d]);
            b.iter_mark();
            b.mma(8, 24, 2048, d, vec![d]);
            b.mma(8, 24, 2048, d, vec![d]); // second segment does double work
            b.iter_mark();
        }
        Rule::PrologueSkew => {
            let d = b.init_reg();
            b.mma(8, 24, 2048, d, vec![d]);
            b.mma(8, 24, 2048, d, vec![d]); // prologue does double work
            b.iter_mark();
            b.mma(8, 24, 2048, d, vec![d]);
            b.iter_mark();
            b.mma(8, 24, 2048, d, vec![d]);
            b.iter_mark();
        }
        Rule::RegisterPressure => {
            for _ in 0..257 {
                b.init_reg();
            }
        }
        Rule::ZeroCostOp => {
            let d = b.init_reg();
            b.mma(0, 0, 2048, d, vec![d]); // ii/latency 0 simulate for free
        }
        Rule::SmemOverflow => {
            // two warps each keep just over half the SM's smem in flight
            b.push(Op::CpAsync { bytes: cap / 2 + 1 }, None, vec![]);
            b.push(Op::CpAsyncCommit, None, vec![]);
            let w0 = b.build();
            let mut b1 = ProgramBuilder::new();
            b1.push(Op::CpAsync { bytes: cap / 2 + 1 }, None, vec![]);
            b1.push(Op::CpAsyncCommit, None, vec![]);
            return vec![w0, b1.build()];
        }
    }
    vec![b.build()]
}

#[test]
fn each_rule_has_a_minimal_triggering_program() {
    let mut covered = BTreeSet::new();
    for rule in Rule::ALL {
        let found = diags(broken_launch(rule));
        assert_eq!(
            ids(&found),
            vec![rule.id()],
            "the {} trigger program must fire exactly that rule",
            rule.id()
        );
        assert_eq!(found[0].severity, rule.severity(), "{}", rule.id());
        covered.insert(rule.id());
    }
    // the loop above walked the whole catalog — no rule is untested
    assert_eq!(covered.len(), Rule::ALL.len());
}

#[test]
fn single_oversized_transfer_is_an_smem_overflow() {
    // the other SmemOverflow arm: one smem op larger than the SM itself
    let cap = device::a100().smem_bytes_per_sm as u64;
    let mut b = ProgramBuilder::new();
    let d = b.alloc_reg();
    b.smem_load(4, cap + 1, d);
    let found = diags(vec![b.build()]);
    assert_eq!(ids(&found), vec!["resource/smem-overflow"], "{found:?}");
    assert_eq!(found[0].instr, Some(0));
}

/// The debug-build contract: `SmSim` refuses to construct over a
/// malformed launch, naming the rule in the panic. Release builds skip
/// the pass (the simulate path stays bit-identical), so this test only
/// exists under `debug_assertions` — exactly like the hook it pins.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "def-use/undefined-read")]
fn debug_sim_construction_rejects_malformed_programs() {
    let mut b = ProgramBuilder::new();
    let d = b.alloc_reg();
    b.mma(8, 24, 2048, d, vec![d]);
    let dev = device::a100();
    let _ = SmSim::new(&dev, vec![b.build()]);
}

// ----------------------------------------------------------- POST /v1/lint

fn start() -> Server {
    Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        warm: false,
        disk_cache: None,
        cache_capacity: 16,
    })
    .expect("tcserved start")
}

/// One raw HTTP exchange; returns (status, body).
fn request_raw(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send request");
    stream.flush().unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {response:?}"))
        .parse()
        .expect("numeric status");
    let body = response.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn post(addr: SocketAddr, target: &str, body: &str) -> (u16, Json) {
    let (status, text) = request_raw(
        addr,
        &format!(
            "POST {target} HTTP/1.1\r\nHost: tcserved\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        ),
    );
    let json = Json::parse(&text)
        .unwrap_or_else(|e| panic!("POST {target}: body is not JSON ({e}): {text:?}"));
    (status, json)
}

#[test]
fn lint_endpoint_over_a_real_socket() {
    let server = start();
    let addr = server.addr();

    // clean plan: 200 with an empty diagnostics array
    let clean = r#"{"workload":"ldmatrix x4","device":"a100","sweep":true}"#;
    let (status, j) = post(addr, "/v1/lint", clean);
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get_str("workload"), Some("ldmatrix x4"));
    assert_eq!(j.get_u64("errors"), Some(0));
    assert!(j.get("diagnostics").unwrap().as_arr().unwrap().is_empty(), "{j}");

    // a compilable but structurally broken plan: a 4-deep cp.async
    // pipeline over 128x128x128 tiles overcommits the A100's shared
    // memory → 400 carrying the rule id
    let overflow = r#"{"workload":"gemm pipeline bf16 f32 2048 128x128x128",
                       "device":"a100","points":[[8,4]]}"#;
    let (status, j) = post(addr, "/v1/lint", overflow);
    assert_eq!(status, 400, "{j}");
    assert!(j.get_u64("errors").unwrap() >= 1, "{j}");
    let rules: Vec<_> = j
        .get("diagnostics")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|d| d.get_str("rule"))
        .collect();
    assert!(rules.contains(&"resource/smem-overflow"), "{rules:?}");

    // malformed body: 400 with the standard error envelope
    let (status, j) = post(addr, "/v1/lint", r#"{"workload":"nonsense"}"#);
    assert_eq!(status, 400);
    assert!(j.get_str("error").is_some(), "{j}");
}
