//! Integration: the unified Workload/BenchPlan API, end to end —
//! builder validation at the library level, `POST /v1/plan` over real
//! sockets (happy path, malformed JSON, method errors), and the
//! per-unit content-addressed cache observed through `/v1/metrics`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use tcbench::server::{Server, ServerConfig};
use tcbench::util::Json;
use tcbench::workload::{Plan, SimRunner, Workload};

fn start() -> Server {
    Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 8,
        warm: false,
        disk_cache: None,
        cache_capacity: 64,
        // keep the process-global cell cache memory-only in this binary
        cell_store: None,
        ..ServerConfig::default()
    })
    .expect("tcserved start")
}

/// Unwrap a `tcserved/v1` success envelope into its `data` payload.
fn data(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    assert!(j.get("error").is_none(), "unexpected error envelope: {j}");
    j.get("data").unwrap_or_else(|| panic!("no data in {j}")).clone()
}

/// Unwrap a `tcserved/v1` error envelope into its `error` object.
fn error_of(j: &Json) -> Json {
    assert_eq!(j.get_str("schema"), Some("tcserved/v1"), "{j}");
    assert!(j.get("data").is_none(), "unexpected success envelope: {j}");
    j.get("error").unwrap_or_else(|| panic!("no error in {j}")).clone()
}

/// One raw HTTP exchange; returns (status, body).
fn request_raw(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send request");
    stream.flush().unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {response:?}"))
        .parse()
        .expect("numeric status");
    let body = response.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn get(addr: SocketAddr, target: &str) -> (u16, Json) {
    let (status, body) = request_raw(
        addr,
        &format!("GET {target} HTTP/1.1\r\nHost: tcserved\r\nConnection: close\r\n\r\n"),
    );
    (status, Json::parse(&body).expect("JSON body"))
}

fn post_plan(addr: SocketAddr, body: &str) -> (u16, Json) {
    let (status, response) = request_raw(
        addr,
        &format!(
            "POST /v1/plan HTTP/1.1\r\nHost: tcserved\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    );
    let json = Json::parse(&response)
        .unwrap_or_else(|e| panic!("POST /v1/plan: body is not JSON ({e}): {response:?}"));
    (status, json)
}

// ------------------------------------------------------ library surface

#[test]
fn every_workload_kind_runs_through_one_plan_path() {
    // the acceptance bar of the unified API: all five instruction
    // families compile and run through the same Plan -> Runner pipeline
    let paper_anchored: [(&str, Option<std::ops::Range<f64>>); 6] = [
        ("mma fp16 f32 m16n8k16", Some(960.0..1030.0)), // Table 3 (8,2)
        ("mma.sp bf16 f32 m16n8k32", Some(1850.0..2150.0)), // ~2x dense, §6
        ("ldmatrix x4", Some(110.0..135.0)),            // §7: ~128 B/clk fabric bound
        ("ld.shared u32 1", None),                      // sanity-only (no paper point at (8,2))
        ("wmma fp16 f32 m16n16k16", Some(850.0..1030.0)), // compiled HMMA pair, §2.2
        ("gemm pipeline bf16 f32 256 128x128x32", None), // Appendix A, (warps, stages) point
    ];
    for (spec, expect_thr) in paper_anchored {
        let workload = Workload::parse_spec(spec).unwrap();
        let plan = Plan::new(workload)
            .device("a100")
            .point(8, 2)
            .completion_latency()
            .compile()
            .unwrap_or_else(|e| panic!("{spec}: {e}"));
        let result = plan.run(&SimRunner, 2).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert!(result.completion().unwrap() > 0.0, "{spec}");
        let m = result.point(8, 2).unwrap_or_else(|| panic!("{spec}: missing point"));
        assert!(m.throughput > 0.0 && m.latency > 0.0, "{spec}: {m:?}");
        if let Some(range) = expect_thr {
            assert!(
                range.contains(&m.throughput),
                "{spec}: throughput {} outside {range:?}",
                m.throughput
            );
        }
    }
}

#[test]
fn builder_validation_errors_are_actionable() {
    let k16 = Workload::parse_spec("mma bf16 f32 m16n8k16").unwrap();
    let err = Plan::new(k16).compile().unwrap_err();
    assert!(err.contains("empty plan"), "{err}");
    let err = Plan::new(k16).device("h100").sweep().compile().unwrap_err();
    assert!(err.contains("unknown device"), "{err}");
    let sp = Workload::parse_spec("mma.sp fp16 f32 m16n8k32").unwrap();
    let err = Plan::new(sp).device("rtx2080ti").sweep().compile().unwrap_err();
    assert!(err.contains("not supported"), "{err}");
}

// ------------------------------------------------------- POST /v1/plan

#[test]
fn plan_endpoint_happy_path() {
    let server = start();
    let addr = server.addr();

    let body = r#"{"workload":"mma bf16 f32 m16n8k16","device":"a100",
                   "points":[[8,2]],"completion_latency":true,"backend":"native"}"#;
    let (status, j) = post_plan(addr, body);
    assert_eq!(status, 200, "{j}");
    let j = data(&j);
    assert_eq!(j.get_str("workload"), Some("mma bf16 f32 m16n8k16"));
    assert_eq!(j.get_str("device"), Some("a100"));
    assert_eq!(j.get_str("backend"), Some("sim"));
    assert_eq!(j.get_u64("count"), Some(2));
    let units = j.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 2);

    let completion = units
        .iter()
        .find(|u| u.get_str("unit") == Some("completion"))
        .expect("completion unit");
    let lat = completion.get("result").unwrap().get_f64("latency").unwrap();
    assert!((24.0..27.0).contains(&lat), "completion {lat}");

    let point = units
        .iter()
        .find(|u| u.get_str("unit").map(|s| s.starts_with("point")) == Some(true))
        .expect("point unit");
    let result = point.get("result").unwrap();
    assert_eq!(result.get_u64("warps"), Some(8));
    assert_eq!(result.get_u64("ilp"), Some(2));
    let thr = result.get_f64("throughput").unwrap();
    assert!((960.0..1030.0).contains(&thr), "throughput {thr}");
    assert!(result.get_str("key").is_some(), "per-unit content address: {result}");

    server.stop();
}

#[test]
fn plan_endpoint_sweep_unit_matches_sweep_endpoint_shape() {
    let server = start();
    let addr = server.addr();

    let body = r#"{"workload":"ldmatrix x4","sweep":true,"convergence":[4],"backend":"native"}"#;
    let (status, j) = post_plan(addr, body);
    assert_eq!(status, 200, "{j}");
    let j = data(&j);
    let units = j.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let sweep = units[0].get("result").unwrap();
    assert_eq!(sweep.get("cells").unwrap().as_arr().unwrap().len(), 48);
    assert_eq!(sweep.get("convergence").unwrap().as_arr().unwrap().len(), 1);
    let peak = sweep.get_f64("peak_throughput").unwrap();
    assert!((115.0..135.0).contains(&peak), "ldmatrix peak {peak}");

    server.stop();
}

#[test]
fn plan_endpoint_malformed_json_is_400() {
    let server = start();
    let addr = server.addr();

    let (status, j) = post_plan(addr, "{\"workload\": ");
    assert_eq!(status, 400);
    let err = error_of(&j);
    assert_eq!(err.get_str("code"), Some("invalid_json"), "{err}");
    assert_eq!(err.get_u64("status"), Some(400));

    // schema-valid JSON but not a plan
    let (status, j) = post_plan(addr, r#"{"workload":"mma bf16 f32 m16n8k16","typo":true}"#);
    assert_eq!(status, 400);
    let err = error_of(&j);
    assert_eq!(err.get_str("code"), Some("invalid_plan"), "{err}");
    assert!(err.get_str("message").unwrap().contains("typo"), "{err}");

    // GET on the POST-only route
    let (status, j) = get(addr, "/v1/plan");
    assert_eq!(status, 405);
    let err = error_of(&j);
    assert_eq!(err.get_str("code"), Some("method_not_allowed"), "{err}");
    assert!(err.get_str("message").unwrap().contains("POST"), "{err}");

    server.stop();
}

#[test]
fn expect_100_continue_gets_an_interim_response() {
    // curl sends `Expect: 100-continue` for larger -d bodies and waits
    // ~1 s for the interim response; the server must provide it
    let server = start();
    let addr = server.addr();
    let body = r#"{"workload":"ld.shared u32 2","points":[[1,1]],"backend":"native"}"#;
    let request = format!(
        "POST /v1/plan HTTP/1.1\r\nHost: tcserved\r\nExpect: 100-continue\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let (interim_status, rest) = request_raw(addr, &request);
    assert_eq!(interim_status, 100, "interim response first: {rest:?}");
    // the final response follows on the same connection
    let (head, final_body) = rest.split_once("\r\n\r\n").expect("final response present");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    let j = data(&Json::parse(final_body).expect("final body is JSON"));
    assert_eq!(j.get_u64("count"), Some(1));

    server.stop();
}

#[test]
fn gemm_plan_round_trip_and_cache() {
    let server = start();
    let addr = server.addr();

    let body = r#"{"workload":"gemm pipeline bf16 f32 256 128x128x32","device":"a100",
                   "points":[[8,2]],"backend":"native"}"#;
    let (status, j1) = post_plan(addr, body);
    assert_eq!(status, 200, "{j1}");
    let j1 = data(&j1);
    assert_eq!(j1.get_str("workload"), Some("gemm pipeline bf16 f32 256 128x128x32"));
    assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
    let units = j1.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let result = units[0].get("result").unwrap();
    assert_eq!(result.get_u64("warps"), Some(8));
    assert_eq!(result.get_u64("ilp"), Some(2)); // = cp.async stage depth
    assert!(result.get_f64("throughput").unwrap() > 0.0, "{result}");
    assert!(result.get_str("key").is_some(), "per-unit content address: {result}");

    // the identical request is served from the per-unit cache...
    let (_, j2) = post_plan(addr, body);
    let j2 = data(&j2);
    assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{j2}");
    // ...observably: /v1/metrics shows exactly one plan compute
    let m = data(&get(addr, "/v1/metrics").1);
    let plan_stat = m.get("experiments").unwrap().get("plan").unwrap();
    assert_eq!(plan_stat.get_u64("computes"), Some(1), "{m}");
    assert!(m.get("cache").unwrap().get_u64("hits").unwrap() >= 1, "{m}");

    // a different stage depth is a different content address
    let deeper = r#"{"workload":"gemm pipeline bf16 f32 256 128x128x32","device":"a100",
                     "points":[[8,3]],"backend":"native"}"#;
    let (_, j3) = post_plan(addr, deeper);
    let j3 = data(&j3);
    let units3 = j3.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units3[0].get_str("origin"), Some("computed"), "{j3}");

    // malformed gemm plans are 400s (parse-time, compile-time, off-grid
    // warp counts), never 500s
    for bad in [
        r#"{"workload":"gemm pipeline bf16 f32 256 100x128x32","points":[[8,2]]}"#,
        r#"{"workload":"gemm pipeline bf16 f32 256 128x128","points":[[8,2]]}"#,
        r#"{"workload":"gemm pipeline bf16 f32 256 128x128x32","points":[[6,2]]}"#,
    ] {
        let (status, j) = post_plan(addr, bad);
        assert_eq!(status, 400, "{bad}: {j}");
        assert_eq!(error_of(&j).get_str("code"), Some("invalid_plan"), "{j}");
    }

    server.stop();
}

#[test]
fn numeric_plan_cache_hit_is_observable_via_metrics() {
    let server = start();
    let addr = server.addr();

    // a §8 probe as a plan: first POST computes on the runner's numeric
    // leg, the identical re-POST is a per-unit cache hit
    let body = r#"{"workload":"numeric profile bf16 f32 acc fp32","device":"a100",
                   "points":[[1,1]],"backend":"native"}"#;
    let (status, j1) = post_plan(addr, body);
    assert_eq!(status, 200, "{j1}");
    let j1 = data(&j1);
    assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));
    let units = j1.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let result = units[0].get("result").unwrap();
    assert_eq!(result.get_str("unit"), Some("numeric"));
    assert_eq!(result.get_str("op"), Some("acc"));
    // Table 12's init_FP32 accumulation row: ~1.1e-3
    let err = result.get_f64("mean_abs_err").unwrap();
    assert!((1e-4..1e-2).contains(&err), "{err:e}");
    assert!(result.get_str("key").is_some(), "per-unit content address: {result}");

    let (_, j2) = post_plan(addr, body);
    let j2 = data(&j2);
    assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{j2}");
    let units2 = j2.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units2[0].get_str("origin"), Some("memory"), "{j2}");

    // /v1/metrics proves it: exactly one plan compute, >= 1 cache hit
    let m = data(&get(addr, "/v1/metrics").1);
    let plan_stat = m.get("experiments").unwrap().get("plan").unwrap();
    assert_eq!(plan_stat.get_u64("computes"), Some(1), "{m}");
    assert!(m.get("cache").unwrap().get_u64("hits").unwrap() >= 1, "{m}");

    // a probe differing only in init is a distinct content address
    let low = r#"{"workload":"numeric profile bf16 f32 acc low","device":"a100",
                  "points":[[1,1]],"backend":"native"}"#;
    let (_, j3) = post_plan(addr, low);
    let j3 = data(&j3);
    let units3 = j3.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units3[0].get_str("origin"), Some("computed"), "{j3}");
    let m2 = data(&get(addr, "/v1/metrics").1);
    let plan_stat2 = m2.get("experiments").unwrap().get("plan").unwrap();
    assert_eq!(plan_stat2.get_u64("computes"), Some(2), "{m2}");

    server.stop();
}

#[test]
fn plan_rerun_hits_the_per_unit_cache() {
    let server = start();
    let addr = server.addr();

    let body = r#"{"workload":"ld.shared u64 8","device":"a100",
                   "points":[[1,1]],"completion_latency":true,"backend":"native"}"#;
    let (status, j1) = post_plan(addr, body);
    assert_eq!(status, 200, "{j1}");
    let j1 = data(&j1);
    assert_eq!(j1.get("cached").and_then(Json::as_bool), Some(false));

    let (_, j2) = post_plan(addr, body);
    let j2 = data(&j2);
    assert_eq!(j2.get("cached").and_then(Json::as_bool), Some(true), "{j2}");
    for unit in j2.get("units").unwrap().as_arr().unwrap() {
        assert_eq!(unit.get("cached").and_then(Json::as_bool), Some(true), "{unit}");
        assert_eq!(unit.get_str("origin"), Some("memory"), "{unit}");
    }

    // /v1/metrics proves it: two plan units computed exactly once each,
    // and the identical re-run produced only cache hits
    let m = data(&get(addr, "/v1/metrics").1);
    let plan_stat = m.get("experiments").unwrap().get("plan").unwrap();
    assert_eq!(plan_stat.get_u64("computes"), Some(2), "{m}");
    assert!(m.get("cache").unwrap().get_u64("hits").unwrap() >= 2, "{m}");

    // a plan differing only in ILP is a distinct content address:
    // its unit computes instead of hitting the cache
    let body_ilp2 = r#"{"workload":"ld.shared u64 8","device":"a100",
                        "points":[[1,2]],"backend":"native"}"#;
    let (_, j3) = post_plan(addr, body_ilp2);
    let j3 = data(&j3);
    let units3 = j3.get("units").unwrap().as_arr().unwrap();
    assert_eq!(units3[0].get_str("origin"), Some("computed"), "{j3}");
    let m2 = data(&get(addr, "/v1/metrics").1);
    let plan_stat2 = m2.get("experiments").unwrap().get("plan").unwrap();
    assert_eq!(plan_stat2.get_u64("computes"), Some(3), "{m2}");

    server.stop();
}
