//! Integration tests of the cell-level execution engine: the parallel,
//! cell-cached sweep path must be **bit-identical** to cold uncached
//! measurement across every workload family, and the cache must be
//! observable where the ISSUE promises it (a point after a sweep, the
//! completion probe after cell (1,1)).

use tcbench::device::a100;
use tcbench::workload::{cell_cache_stats, CellCache, ExecPoint, Plan, SimRunner, Workload};

/// One representative of each of the seven workload families.
fn families() -> Vec<Workload> {
    [
        "mma bf16 f32 m16n8k16",
        "mma.sp fp16 f32 m16n8k32",
        "ldmatrix x4",
        "ld.shared u32 8",
        "wmma fp16 f32 m16n16k16",
        "gemm pipeline bf16 f32 256 128x128x32",
        "numeric chain tf32 f32 4",
    ]
    .into_iter()
    .map(|spec| Workload::parse_spec(spec).expect(spec))
    .collect()
}

#[test]
fn cell_cached_sweep_is_bit_identical_to_cold_uncached_measurement() {
    let d = a100();
    for w in families() {
        // engine path: parallel cells, read/written through the global
        // cell cache (in whatever hit/miss mix earlier tests left it in)
        let s1 = w.sweep(&d);
        assert_eq!(
            s1.cells.len(),
            s1.warps_axis.len() * s1.ilp_axis.len(),
            "{w}: grid must be complete"
        );

        // cold path: raw per-cell measurement, no cache, serial — the
        // pre-engine semantics (numeric sweeps have no timing cells;
        // their grid is compared engine-vs-engine below)
        if !matches!(w, Workload::Numeric(_)) {
            let mut idx = 0;
            for &warps in &s1.warps_axis {
                for &ilp in &s1.ilp_axis {
                    let cold = w.measure(&d, ExecPoint::new(warps, ilp));
                    let cell = &s1.cells[idx];
                    assert_eq!((cell.warps, cell.ilp), (warps, ilp), "{w}: cell order");
                    assert_eq!(
                        cell.latency.to_bits(),
                        cold.latency.to_bits(),
                        "{w} ({warps},{ilp}): latency must be bit-identical"
                    );
                    assert_eq!(
                        cell.throughput.to_bits(),
                        cold.throughput.to_bits(),
                        "{w} ({warps},{ilp}): throughput must be bit-identical"
                    );
                    idx += 1;
                }
            }
        }

        // a second engine sweep is served from the cache and is
        // bit-identical too
        let hits_before = cell_cache_stats().hits;
        let s2 = w.sweep(&d);
        for (a, b) in s1.cells.iter().zip(&s2.cells) {
            assert_eq!(a.latency.to_bits(), b.latency.to_bits(), "{w}");
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{w}");
        }
        if !matches!(w, Workload::Numeric(_)) {
            let hits_after = cell_cache_stats().hits;
            assert!(
                hits_after >= hits_before + s1.cells.len() as u64,
                "{w}: rerun must be all cell hits ({hits_before} -> {hits_after})"
            );
        }
    }
}

#[test]
fn point_unit_after_a_sweep_is_a_cell_hit() {
    // a (workload, device) pair no other test sweeps, so the traffic
    // delta below is attributable
    let w = Workload::parse_spec("ld.shared u64 32").unwrap();
    let sweep = Plan::new(w).sweep().compile().unwrap();
    sweep.run(&SimRunner, 2).unwrap();
    // deterministic: the sweep populated exactly the cell the point
    // unit will ask for
    assert!(CellCache::global().contains("ld.shared u64 32", "a100", ExecPoint::new(4, 2), "sim"));

    let hits_before = cell_cache_stats().hits;
    let point = Plan::new(w).point(4, 2).compile().unwrap();
    let r = point.run(&SimRunner, 1).unwrap();
    assert!(r.point(4, 2).unwrap().latency > 0.0);
    assert!(
        cell_cache_stats().hits > hits_before,
        "a point inside an already-swept grid must not resimulate"
    );
}

#[test]
fn ad_hoc_devices_measure_uncached_instead_of_aliasing_registry_cells() {
    let w = Workload::parse_spec("ld.shared u32 4").unwrap();
    let d = a100();
    let p = ExecPoint::new(1, 1);
    let registry = w.measure_cached(&d, p, "sim");

    // same registry name, different calibration: must NOT be served the
    // registry device's cached cell
    let mut tweaked = a100();
    tweaked.lsu_txn_cycles *= 2;
    let ad_hoc = w.measure_cached(&tweaked, p, "sim");
    assert!(
        ad_hoc.latency > registry.latency,
        "slower fabric must show: {} vs {}",
        ad_hoc.latency,
        registry.latency
    );
    assert_eq!(
        ad_hoc.latency.to_bits(),
        w.measure(&tweaked, p).latency.to_bits(),
        "ad-hoc devices take the raw measure path"
    );
    // and the ad-hoc sweep path stays correct too (fully uncached)
    let sweep = w.sweep_via(&tweaked, "sim", 2);
    assert_eq!(
        sweep.cell(1, 1).unwrap().latency.to_bits(),
        ad_hoc.latency.to_bits()
    );
}

#[test]
fn completion_probe_reuses_cell_1_1() {
    let w = Workload::parse_spec("ld.shared u64 16").unwrap();
    let point = Plan::new(w).point(1, 1).compile().unwrap();
    let pr = point.run(&SimRunner, 1).unwrap();
    assert!(CellCache::global().contains("ld.shared u64 16", "a100", ExecPoint::new(1, 1), "sim"));

    let hits_before = cell_cache_stats().hits;
    let completion = Plan::new(w).completion_latency().compile().unwrap();
    let cr = completion.run(&SimRunner, 1).unwrap();
    // completion IS cell (1,1): same bits, no second simulation
    assert_eq!(
        cr.completion().unwrap().to_bits(),
        pr.point(1, 1).unwrap().latency.to_bits()
    );
    assert!(
        cell_cache_stats().hits > hits_before,
        "completion_latency must read cell (1,1) through the cache"
    );
}
