"""Precision-quantization primitives for the emulated Tensor-Core datapath.

The paper (Section 8, Table 11) studies three low-precision floating-point
input types supported by Ampere Tensor Cores:

    ===========  ====  ========  ========  ========
    type         sign  exponent  mantissa  register
    ===========  ====  ========  ========  ========
    FP32          1       8         23       32b
    TF32          1       8         10       32b
    FP16          1       5         10       16b
    BF16          1       8          7       16b
    ===========  ====  ========  ========  ========

The hardware quantizes FP32 inputs to the operand type with
round-to-nearest-even (RNE), multiplies exactly, adds the k-term inner
product at high precision, and performs the accumulation `[A@B] + C` in
FP32 with a type-dependent rounding mode (RNE for the FP16/TF32 paths, RZ
for the BF16 path — the calibration that reproduces the paper's Table 12;
see DESIGN.md §4).

Everything here is pure jax.numpy so it can be used both inside the Pallas
kernel (L1) and in the plain-jnp model (L2), and lowers to ordinary HLO
ops under `interpret=True`.
"""

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_bf16",
    "quantize_fp16",
    "quantize_tf32",
    "quantize",
    "round_f64_to_f32_rne",
    "round_f64_to_f32_rz",
    "round_f64_to_f32",
    "AB_DTYPES",
]

# Operand (A/B) types supported by the emulated datapath.
AB_DTYPES = ("bf16", "fp16", "tf32")


def quantize_bf16(x: jax.Array) -> jax.Array:
    """FP32 -> BF16 -> FP32 (RNE). BF16 keeps FP32's 8-bit exponent."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def quantize_fp16(x: jax.Array) -> jax.Array:
    """FP32 -> FP16 -> FP32 (RNE). Values beyond ±65504 overflow to ±inf,
    which is exactly the paper's Fig. 17 failure mode for FP16 chains."""
    return x.astype(jnp.float16).astype(jnp.float32)


def quantize_tf32(x: jax.Array) -> jax.Array:
    """FP32 -> TF32 -> FP32 (RNE ties-to-even on the 10-bit mantissa).

    TF32 is stored in a 32-bit register (Table 11): same 8-bit exponent as
    FP32, mantissa truncated from 23 to 10 bits. Implemented with integer
    bit manipulation; NaN/Inf (exponent all-ones) pass through untouched.
    """
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    exp_all_ones = (bits >> jnp.uint32(23)) & jnp.uint32(0xFF) == jnp.uint32(0xFF)
    # RNE on the low 13 bits: add 0x0FFF + lsb-of-kept-part, then mask.
    lsb = (bits >> jnp.uint32(13)) & jnp.uint32(1)
    rounded = (bits + jnp.uint32(0x0FFF) + lsb) & ~jnp.uint32(0x1FFF)
    out = jnp.where(exp_all_ones, bits, rounded)
    return jax.lax.bitcast_convert_type(out, jnp.float32)


_QUANTIZERS = {
    "bf16": quantize_bf16,
    "fp16": quantize_fp16,
    "tf32": quantize_tf32,
    # identity is handy for oracles / ablations
    "fp32": lambda x: x,
}


def quantize(x: jax.Array, dtype: str) -> jax.Array:
    """Quantize an FP32 array to `dtype` and back (RNE)."""
    try:
        return _QUANTIZERS[dtype](x)
    except KeyError:
        raise ValueError(f"unknown operand dtype {dtype!r}") from None


def round_f64_to_f32_rne(x: jax.Array) -> jax.Array:
    """Round a float64 array to float32, round-to-nearest-even."""
    return x.astype(jnp.float32)


def round_f64_to_f32_rz(x: jax.Array) -> jax.Array:
    """Round a float64 array to float32, round-toward-zero (truncation).

    The default f64->f32 cast is RNE; when it rounded *away* from zero we
    step one ulp back toward zero with nextafter. (If the cast rounded
    toward zero, RNE and RZ agree.)
    """
    y = x.astype(jnp.float32)
    stepped = jnp.nextafter(y, jnp.zeros_like(y))
    away = jnp.abs(y.astype(jnp.float64)) > jnp.abs(x)
    return jnp.where(away, stepped, y)


def round_f64_to_f32(x: jax.Array, mode: str) -> jax.Array:
    """Round f64 -> f32 with the named mode ('rne' | 'rz')."""
    if mode == "rne":
        return round_f64_to_f32_rne(x)
    if mode == "rz":
        return round_f64_to_f32_rz(x)
    raise ValueError(f"unknown rounding mode {mode!r}")
