"""Pure-numpy correctness oracle for the emulated Tensor-Core MMA kernel.

Deliberately *independent* of the jnp/Pallas implementation: quantization
is done through ml_dtypes casts / explicit integer bit twiddling, the
inner product is an explicit per-element Python loop over float64, and the
RZ rounding is implemented via nextafter on the RNE cast. pytest compares
the Pallas kernel against this oracle (python/tests/test_kernel.py).
"""

import math

import ml_dtypes
import numpy as np

__all__ = [
    "ref_quantize",
    "ref_round_f64_to_f32",
    "ref_tcmma_tile",
    "ref_tcmma",
]


def _quantize_tf32_scalar(x: np.float32) -> np.float32:
    bits = np.float32(x).view(np.uint32)
    exp = (int(bits) >> 23) & 0xFF
    if exp == 0xFF:  # inf / nan pass through
        return np.float32(x)
    b = int(bits)
    lsb = (b >> 13) & 1
    b = (b + 0x0FFF + lsb) & 0xFFFFFFFF
    b &= ~0x1FFF & 0xFFFFFFFF
    return np.uint32(b).view(np.float32)


def ref_quantize(x: np.ndarray, dtype: str) -> np.ndarray:
    """FP32 -> low precision -> FP32, RNE. x is a float32 ndarray."""
    x = np.asarray(x, dtype=np.float32)
    if dtype == "bf16":
        return x.astype(ml_dtypes.bfloat16).astype(np.float32)
    if dtype == "fp16":
        return x.astype(np.float16).astype(np.float32)
    if dtype == "tf32":
        out = np.empty_like(x)
        flat_in, flat_out = x.ravel(), out.ravel()
        for i, v in enumerate(flat_in):
            flat_out[i] = _quantize_tf32_scalar(v)
        return out
    if dtype == "fp32":
        return x
    raise ValueError(f"unknown operand dtype {dtype!r}")


def ref_round_f64_to_f32(x: float, mode: str) -> np.float32:
    """Round a python/f64 scalar to f32 with 'rne' or 'rz'."""
    y = np.float32(x)
    if mode == "rne":
        return y
    if mode == "rz":
        if math.isinf(float(y)) and math.isfinite(x):
            # RZ never rounds a finite value to infinity.
            return np.float32(math.copysign(float(np.finfo(np.float32).max), x))
        if not math.isfinite(float(y)):
            return y
        if abs(float(y)) > abs(x):
            return np.nextafter(y, np.float32(0.0), dtype=np.float32)
        return y
    raise ValueError(f"unknown rounding mode {mode!r}")


def ref_tcmma_tile(a, b, c, ab: str, cd: str, acc_rnd: str) -> np.ndarray:
    """One (m,k)x(k,n)+(m,n) tile through the reference datapath."""
    a = ref_quantize(np.asarray(a, np.float32), ab)
    b = ref_quantize(np.asarray(b, np.float32), ab)
    c = np.asarray(c, np.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k2 == k and c.shape == (m, n)
    out = np.empty((m, n), dtype=np.float32)
    for i in range(m):
        for j in range(n):
            s = 0.0  # float64 accumulator — the "wide adder"
            for p in range(k):
                s += float(a[i, p]) * float(b[p, j])
            s32 = np.float32(s)  # inner product rounds once, RNE
            d = ref_round_f64_to_f32(float(s32) + float(c[i, j]), acc_rnd)
            if cd == "f16":
                d = np.float32(np.float16(d))
            out[i, j] = d
    return out


def ref_tcmma(a, b, c, ab: str, cd: str, acc_rnd: str) -> np.ndarray:
    """Batched reference: f32[B,m,k] x f32[B,k,n] + f32[B,m,n]."""
    a, b, c = (np.asarray(x, np.float32) for x in (a, b, c))
    return np.stack(
        [ref_tcmma_tile(a[i], b[i], c[i], ab, cd, acc_rnd) for i in range(a.shape[0])]
    )
