"""L1 Pallas kernel: emulated Tensor-Core fused MMA (`D = A @ B + C`).

This is the compute hot-spot of the paper's Section 8 numeric experiments:
one `mma`-shaped tile FMA with the Tensor-Core datapath model

    1. quantize A and B to the operand type (RNE),
    2. multiply exactly,
    3. add the k-term inner product at high precision (f64 here),
    4. round the accumulation `[A@B] + C` to FP32 once, with the
       type-dependent accumulation rounding mode,
    5. cast D to the C/D type (FP32 or FP16).

The kernel is batched over independent trials (the paper averages 1000
random trials); the Pallas grid walks the batch dimension so each grid
step keeps one (m,k)x(k,n)+(m,n) working set in VMEM.

Hardware adaptation (DESIGN.md §2): the paper's per-warp register
fragments + `ldmatrix` staging become a BlockSpec index_map that stages
one trial tile per grid step — the HBM->VMEM schedule is the TPU analogue
of the smem->register-file movement the paper microbenchmarks.

Pallas runs with `interpret=True` so the lowered HLO executes on the CPU
PJRT client (real TPU lowering emits a Mosaic custom-call the CPU plugin
cannot run).
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import AB_DTYPES, quantize, round_f64_to_f32

__all__ = ["TcMmaConfig", "CONFIGS", "tcmma", "tcmma_tile"]


@dataclass(frozen=True)
class TcMmaConfig:
    """Numeric configuration of one emulated Tensor-Core instruction.

    `ab`      — operand type of matrices A and B ('bf16' | 'fp16' | 'tf32')
    `cd`      — accumulator/result type of C and D ('f32' | 'f16')
    `acc_rnd` — rounding mode of the FP32 accumulation step. Calibrated to
                the paper's Table 12/13/15: 'rz' for the BF16 path, 'rne'
                for FP16/TF32 (DESIGN.md §4).
    """

    ab: str
    cd: str = "f32"

    def __post_init__(self):
        if self.ab not in AB_DTYPES:
            raise ValueError(f"operand dtype must be one of {AB_DTYPES}")
        if self.cd not in ("f32", "f16"):
            raise ValueError("C/D dtype must be 'f32' or 'f16'")
        if self.ab != "fp16" and self.cd == "f16":
            raise ValueError("FP16 C/D is only supported for FP16 operands")

    @property
    def acc_rnd(self) -> str:
        return "rz" if self.ab == "bf16" else "rne"

    @property
    def name(self) -> str:
        return f"{self.ab}_{self.cd}"


#: The paper's Section-8 instruction variants (Tables 12-15, Fig. 17).
CONFIGS = {
    "bf16_f32": TcMmaConfig("bf16", "f32"),
    "fp16_f32": TcMmaConfig("fp16", "f32"),
    "fp16_f16": TcMmaConfig("fp16", "f16"),
    "tf32_f32": TcMmaConfig("tf32", "f32"),
}


def tcmma_tile(a: jax.Array, b: jax.Array, c: jax.Array, cfg: TcMmaConfig) -> jax.Array:
    """The datapath on one (m,k)x(k,n)+(m,n) tile, plain jnp (f32 in/out).

    Shared by the Pallas kernel body and the L2 model; all arrays are f32
    (FP16 C/D values travel as their exact f32 images).
    """
    aq = quantize(a, cfg.ab)
    bq = quantize(b, cfg.ab)
    # Exact products + high-precision inner product: quantized operands
    # have <=11-bit significands, so the f64 dot is the "infinitely
    # precise multiply + wide adder" stand-in (DESIGN.md §4). The k-term
    # inner product is rounded once (RNE) into an FP32 result register…
    prod = jnp.dot(
        aq.astype(jnp.float64), bq.astype(jnp.float64),
        preferred_element_type=jnp.float64,
    )
    s32 = prod.astype(jnp.float32)
    # …and the accumulation `[A@B] + C` is a second FP32 step with the
    # type-dependent rounding mode (RZ on the BF16 path — Table 12).
    acc = s32.astype(jnp.float64) + c.astype(jnp.float64)
    d32 = round_f64_to_f32(acc, cfg.acc_rnd)
    if cfg.cd == "f16":
        # The hardware computes at high precision and converts the final
        # result to FP16 at the end (paper Table 14 finding).
        d32 = d32.astype(jnp.float16).astype(jnp.float32)
    return d32


def _kernel(a_ref, b_ref, c_ref, o_ref, *, cfg: TcMmaConfig):
    a = a_ref[0]  # (m, k)
    b = b_ref[0]  # (k, n)
    c = c_ref[0]  # (m, n)
    o_ref[0] = tcmma_tile(a, b, c, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def tcmma(a: jax.Array, b: jax.Array, c: jax.Array, cfg: TcMmaConfig) -> jax.Array:
    """Batched emulated Tensor-Core MMA.

    a: f32[B, m, k]   b: f32[B, k, n]   c: f32[B, m, n]  ->  f32[B, m, n]
    """
    if a.ndim != 3 or b.ndim != 3 or c.ndim != 3:
        raise ValueError("tcmma expects batched rank-3 operands")
    batch, m, k = a.shape
    _, k2, n = b.shape
    if k2 != k or b.shape[0] != batch or c.shape != (batch, m, n):
        raise ValueError(
            f"inconsistent operand shapes a={a.shape} b={b.shape} c={c.shape}"
        )
    return pl.pallas_call(
        partial(_kernel, cfg=cfg),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), jnp.float32),
        interpret=True,
    )(a, b, c)
