"""L1: Pallas kernel(s) for the paper's compute hot-spot (emulated
Tensor-Core MMA) plus the quantization primitives and the pure-numpy
correctness oracle."""

from .quantize import (  # noqa: F401
    AB_DTYPES,
    quantize,
    quantize_bf16,
    quantize_fp16,
    quantize_tf32,
    round_f64_to_f32,
    round_f64_to_f32_rne,
    round_f64_to_f32_rz,
)
from .tcmma import CONFIGS, TcMmaConfig, tcmma, tcmma_tile  # noqa: F401
