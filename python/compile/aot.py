"""AOT lowering: L2 model -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes one `<name>.hlo.txt` per artifact plus `manifest.json` describing
every artifact (shape, batch, numeric config) for the Rust loader.
"""

import argparse
import json
import pathlib

import jax

# The kernel's high-precision inner product is f64; enable x64 before any
# tracing happens.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import ARTIFACTS, build_model, example_args  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(spec) -> str:
    model = build_model(spec)
    lowered = jax.jit(model).lower(*example_args(spec))
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--only", default=None, help="comma-separated artifact names (default: all)"
    )
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = args.only.split(",") if args.only else list(ARTIFACTS)

    manifest = {}
    for name in names:
        spec = ARTIFACTS[name]
        text = lower_artifact(spec)
        path = out_dir / spec.filename
        path.write_text(text)
        manifest[name] = {
            "file": spec.filename,
            "ab": spec.cfg.ab,
            "cd": spec.cfg.cd,
            "acc_rnd": spec.cfg.acc_rnd,
            "m": spec.m,
            "n": spec.n,
            "k": spec.k,
            "batch": spec.batch,
        }
        print(f"wrote {path} ({len(text)} chars)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
