"""L2: the JAX compute graphs AOT-lowered for the Rust coordinator.

Each artifact is one batched emulated-Tensor-Core MMA `D = A @ B + C`
(the paper's `mma` instruction, Fig. 5/8) at a fixed numeric config and
operand shape, calling the L1 Pallas kernel. The same executable serves
all of the paper's Section-8 experiments:

  * element-wise profiling (Fig. 16 a/b/c) — the Rust side constructs the
    sparse one-element / one-row input patterns,
  * chain matrix multiplication (Fig. 17) — the Rust side feeds D back as
    the next A with C = 0,

batched over independent random trials.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import CONFIGS, TcMmaConfig, tcmma

__all__ = ["ArtifactSpec", "ARTIFACTS", "build_model", "example_args"]

#: Number of independent trials executed per call. The paper averages
#: 1000 trials; the Rust coordinator runs ceil(1000/TRIALS) executions.
TRIALS = 256


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT artifact: a numeric config at an `mma` operand shape."""

    name: str
    cfg: TcMmaConfig
    m: int
    n: int
    k: int
    batch: int = TRIALS

    @property
    def filename(self) -> str:
        return f"{self.name}.hlo.txt"


def _specs() -> list[ArtifactSpec]:
    """The paper's Section-5/8 instruction variants.

    Shapes follow Table 3's dtype->shape support matrix: BF16/FP16 have
    m16n8k16 and m16n8k8; TF32 has m16n8k8 and m16n8k4. The chain study
    (Fig. 17) uses m16n8k8 for all three types ("this common shape is
    supported by BF16, FP16, and TF32").
    """
    out = []
    for cfg_name, shapes in [
        ("bf16_f32", [(16, 8, 16), (16, 8, 8)]),
        ("fp16_f32", [(16, 8, 16), (16, 8, 8)]),
        ("fp16_f16", [(16, 8, 16), (16, 8, 8)]),
        ("tf32_f32", [(16, 8, 8), (16, 8, 4)]),
    ]:
        cfg = CONFIGS[cfg_name]
        for m, n, k in shapes:
            out.append(
                ArtifactSpec(f"tcmma_{cfg_name}_m{m}n{n}k{k}", cfg, m, n, k)
            )
    return out


ARTIFACTS: dict[str, ArtifactSpec] = {s.name: s for s in _specs()}


def build_model(spec: ArtifactSpec):
    """Return the jittable batched MMA for `spec`.

    f32[B,m,k] x f32[B,k,n] + f32[B,m,n] -> (f32[B,m,n],)
    (1-tuple: the AOT bridge lowers with return_tuple=True and the Rust
    side unwraps with to_tuple1 — see /opt/xla-example/README.md.)
    """

    def model(a, b, c):
        return (tcmma(a, b, c, spec.cfg),)

    return model


def example_args(spec: ArtifactSpec):
    """ShapeDtypeStructs used to lower `spec`."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((spec.batch, spec.m, spec.k), f32),
        jax.ShapeDtypeStruct((spec.batch, spec.k, spec.n), f32),
        jax.ShapeDtypeStruct((spec.batch, spec.m, spec.n), f32),
    )
