"""Build-time compile package: L1 Pallas kernels, L2 JAX model, AOT
lowering to HLO-text artifacts. Never imported on the Rust request path."""
