"""L2 model + AOT pipeline tests: artifact inventory, lowering produces
parseable HLO text with the right entry signature, and the lowered
computation matches the kernel when executed through jax."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import tcmma


def test_artifact_inventory_covers_paper_variants():
    names = set(model.ARTIFACTS)
    # Table 3 dtype->shape support matrix + Fig. 17 common shape
    assert "tcmma_bf16_f32_m16n8k16" in names
    assert "tcmma_bf16_f32_m16n8k8" in names
    assert "tcmma_fp16_f32_m16n8k16" in names
    assert "tcmma_fp16_f16_m16n8k8" in names
    assert "tcmma_tf32_f32_m16n8k8" in names
    assert "tcmma_tf32_f32_m16n8k4" in names
    assert len(names) == 8


def test_example_args_shapes():
    spec = model.ARTIFACTS["tcmma_bf16_f32_m16n8k16"]
    a, b, c = model.example_args(spec)
    assert a.shape == (spec.batch, 16, 16)
    assert b.shape == (spec.batch, 16, 8)
    assert c.shape == (spec.batch, 16, 8)


def test_model_output_is_one_tuple():
    spec = model.ARTIFACTS["tcmma_fp16_f32_m16n8k8"]
    fn = model.build_model(spec)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((spec.batch, 16, 8)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((spec.batch, 8, 8)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((spec.batch, 16, 8)).astype(np.float32))
    out = fn(a, b, c)
    assert isinstance(out, tuple) and len(out) == 1
    want = tcmma(a, b, c, spec.cfg)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(want))


@pytest.mark.parametrize("name", ["tcmma_bf16_f32_m16n8k8", "tcmma_tf32_f32_m16n8k4"])
def test_lowering_emits_hlo_text(name):
    spec = model.ARTIFACTS[name]
    text = aot.lower_artifact(spec)
    assert "ENTRY" in text and "HloModule" in text
    # entry takes the three f32 operands at the right batched shapes
    assert f"f32[{spec.batch},{spec.m},{spec.k}]" in text
    assert f"f32[{spec.batch},{spec.k},{spec.n}]" in text
    # the wide-adder inner product runs in f64
    assert "f64" in text


def test_lowered_hlo_executes_and_matches_kernel():
    """Round-trip the HLO text through xla_client and compare numerics —
    the same path the Rust runtime takes (minus the text re-parse)."""
    spec = model.ARTIFACTS["tcmma_bf16_f32_m16n8k8"]
    fn = model.build_model(spec)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((spec.batch, spec.m, spec.k)).astype(np.float32)
    b = rng.standard_normal((spec.batch, spec.k, spec.n)).astype(np.float32)
    c = rng.standard_normal((spec.batch, spec.m, spec.n)).astype(np.float32)
    jit_out = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))[0])
    want = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), spec.cfg))
    np.testing.assert_array_equal(jit_out, want)


def test_manifest_matches_artifacts(tmp_path):
    """aot.main writes a manifest consistent with ARTIFACTS (single spec
    to keep the test fast)."""
    import sys
    from unittest import mock

    argv = ["aot", "--out", str(tmp_path), "--only", "tcmma_tf32_f32_m16n8k8"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert list(manifest) == ["tcmma_tf32_f32_m16n8k8"]
    entry = manifest["tcmma_tf32_f32_m16n8k8"]
    assert entry["ab"] == "tf32" and entry["cd"] == "f32"
    assert entry["acc_rnd"] == "rne"
    assert (tmp_path / entry["file"]).exists()


def test_repo_artifacts_fresh_if_present():
    """If `make artifacts` has run, the manifest must cover all specs."""
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    manifest_path = art / "manifest.json"
    if not manifest_path.exists():
        pytest.skip("artifacts not built yet")
    manifest = json.loads(manifest_path.read_text())
    assert set(manifest) == set(model.ARTIFACTS)
    for name, entry in manifest.items():
        assert (art / entry["file"]).exists(), name
