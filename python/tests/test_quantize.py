"""Unit tests for the quantization / rounding primitives (L1 building
blocks), including bit-level checks against independently constructed
values and a hypothesis sweep against the numpy oracle."""

import math
import struct

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import importlib

# `compile.kernels.quantize` (module) is shadowed by the re-exported
# `quantize` function on the package; fetch the module explicitly.
q = importlib.import_module("compile.kernels.quantize")
from compile.kernels.ref import ref_quantize, ref_round_f64_to_f32

F32 = np.float32


def bits(x: float) -> int:
    return struct.unpack("<I", struct.pack("<f", F32(x)))[0]


def from_bits(b: int) -> np.float32:
    return np.frombuffer(struct.pack("<I", b), dtype=np.float32)[0]


# ---------------------------------------------------------------- bf16


def test_bf16_mantissa_truncation():
    # 1 + 2^-8 is below bf16's 7-bit mantissa resolution: rounds to 1.0
    x = F32(1.0) + F32(2.0**-8)
    assert q.quantize_bf16(jnp.float32(x)) == F32(1.0)
    # 1 + 2^-7 is exactly representable
    y = F32(1.0) + F32(2.0**-7)
    assert q.quantize_bf16(jnp.float32(y)) == y


def test_bf16_ties_to_even():
    # 1 + 3*2^-8 is exactly between 1+2^-7 and 1+2^-6: ties to even (1+2^-6)
    x = F32(1.0) + F32(3.0 * 2.0**-8)
    got = float(q.quantize_bf16(jnp.float32(x)))
    assert got == float(F32(1.0) + F32(2.0**-6))


def test_bf16_keeps_fp32_range():
    # Values far beyond FP16 range survive bf16 (8-bit exponent)
    x = F32(1e38)
    assert math.isfinite(float(q.quantize_bf16(jnp.float32(x))))


# ---------------------------------------------------------------- fp16


def test_fp16_overflow_to_inf():
    assert math.isinf(float(q.quantize_fp16(jnp.float32(70000.0))))
    assert float(q.quantize_fp16(jnp.float32(65504.0))) == 65504.0


def test_fp16_mantissa_resolution():
    x = F32(1.0) + F32(2.0**-11)
    assert float(q.quantize_fp16(jnp.float32(x))) == 1.0
    y = F32(1.0) + F32(2.0**-10)
    assert float(q.quantize_fp16(jnp.float32(y))) == float(y)


# ---------------------------------------------------------------- tf32


def test_tf32_mantissa_resolution():
    # TF32 keeps 10 mantissa bits: 1+2^-10 representable, 1+2^-11 rounds away
    y = F32(1.0) + F32(2.0**-10)
    assert float(q.quantize_tf32(jnp.float32(y))) == float(y)
    x = F32(1.0) + F32(2.0**-11)
    assert float(q.quantize_tf32(jnp.float32(x))) == 1.0


def test_tf32_ties_to_even():
    # halfway between 1.0 and 1+2^-10 -> ties to even mantissa (1.0)
    x = from_bits(bits(1.0) | (1 << 12))
    assert float(q.quantize_tf32(jnp.float32(x))) == 1.0
    # halfway between 1+2^-10 and 1+2^-9 -> ties up to even (1+2^-9)
    y = from_bits(bits(1.0) | (1 << 13) | (1 << 12))
    assert float(q.quantize_tf32(jnp.float32(y))) == float(F32(1.0) + F32(2.0**-9))


def test_tf32_same_range_as_fp32():
    x = F32(3e38)
    out = float(q.quantize_tf32(jnp.float32(x)))
    assert math.isfinite(out)


def test_tf32_inf_nan_passthrough():
    assert math.isinf(float(q.quantize_tf32(jnp.float32(np.inf))))
    assert math.isnan(float(q.quantize_tf32(jnp.float32(np.nan))))


def test_tf32_lower_bits_cleared():
    rng = np.random.default_rng(3)
    x = rng.standard_normal(256).astype(np.float32)
    out = np.asarray(q.quantize_tf32(jnp.asarray(x)))
    for v in out:
        assert bits(v) & 0x1FFF == 0


# ----------------------------------------------------- idempotence etc.


@pytest.mark.parametrize("dtype", ["bf16", "fp16", "tf32"])
def test_quantize_idempotent(dtype):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    once = q.quantize(x, dtype)
    twice = q.quantize(once, dtype)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@pytest.mark.parametrize("dtype", ["bf16", "fp16", "tf32"])
@given(data=st.lists(st.floats(-1e4, 1e4, width=32), min_size=1, max_size=64))
@settings(max_examples=40, deadline=None)
def test_quantize_matches_oracle(dtype, data):
    x = np.asarray(data, dtype=np.float32)
    got = np.asarray(q.quantize(jnp.asarray(x), dtype))
    want = ref_quantize(x, dtype)
    np.testing.assert_array_equal(got, want)


def test_unknown_dtype_raises():
    with pytest.raises(ValueError):
        q.quantize(jnp.zeros(1, jnp.float32), "fp8")


# ------------------------------------------------------------- rounding


def test_rz_truncates_toward_zero():
    # pick an f64 that RNE rounds up in magnitude
    x = np.float64(1.0) + np.float64(2.0**-24)  # halfway: RNE ties to 1.0
    x_up = np.float64(1.0) + np.float64(2.0**-24) * 1.5  # rounds to 1+2^-23
    got_rne = float(q.round_f64_to_f32(jnp.float64(x_up), "rne"))
    got_rz = float(q.round_f64_to_f32(jnp.float64(x_up), "rz"))
    assert got_rne == float(F32(1.0) + F32(2.0**-23))
    assert got_rz == 1.0
    # negative mirror
    got_rz_neg = float(q.round_f64_to_f32(jnp.float64(-x_up), "rz"))
    assert got_rz_neg == -1.0


def test_rz_exact_values_unchanged():
    rng = np.random.default_rng(5)
    x32 = rng.standard_normal(256).astype(np.float32)
    got = np.asarray(q.round_f64_to_f32(jnp.asarray(x32, jnp.float64), "rz"))
    np.testing.assert_array_equal(got, x32)


def test_rz_magnitude_never_exceeds_input():
    rng = np.random.default_rng(9)
    x = rng.standard_normal(4096).astype(np.float64) * 1e3
    got = np.asarray(q.round_f64_to_f32(jnp.asarray(x), "rz")).astype(np.float64)
    assert (np.abs(got) <= np.abs(x)).all()


def test_rz_overflow_clamps_to_maxfloat():
    big = np.float64(3.5e38)
    got = float(q.round_f64_to_f32(jnp.float64(big), "rz"))
    assert got == float(np.finfo(np.float32).max)


@given(
    # Normal-range floats only: XLA flushes f32 subnormals to zero on CPU
    # while numpy keeps them; the paper's N(0,1) experiments never touch
    # subnormals (subnormal behavior is Fasi et al.'s scope, not ours).
    st.one_of(
        st.floats(min_value=1e-30, max_value=1e6),
        st.floats(min_value=-1e6, max_value=-1e-30),
        st.just(0.0),
    ),
    st.sampled_from(["rne", "rz"]),
)
@settings(max_examples=200, deadline=None)
def test_rounding_matches_oracle(x, mode):
    got = float(q.round_f64_to_f32(jnp.float64(x), mode))
    want = float(ref_round_f64_to_f32(x, mode))
    assert got == want or (math.isnan(got) and math.isnan(want))


def test_unknown_rounding_mode_raises():
    with pytest.raises(ValueError):
        q.round_f64_to_f32(jnp.zeros(1, jnp.float64), "ru")
