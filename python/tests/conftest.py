import jax

# The kernel's high-precision inner product is f64; must be enabled
# before any tracing in any test module.
jax.config.update("jax_enable_x64", True)
