"""Section-8 numeric-behavior signatures, asserted at the Python level
(the Rust coordinator re-runs these through the AOT artifacts; this file
is the build-time gate that the datapath reproduces the paper).

Paper targets:
  Table 12 (BF16):  init_BF16 -> mul 0, inner-product 0, accumulation ~1.9e-8
                    init_FP32 -> all ops ~1e-3
  Table 13 (FP16, C/D=FP32): init_FP16 -> all 0; init_FP32 -> ~1e-4
  Table 14 (FP16, C/D=FP16): vs CPU_FP32 nonzero; vs CPU_FP32cvtFP16 with
                    init_FP16 -> 0
  Table 15 (TF32):  init_TF32 -> all 0; init_FP32 -> ~1e-4 (same level as
                    FP16 — both have 10 mantissa bits)
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import CONFIGS, tcmma
from compile.kernels.ref import ref_quantize

B, M, N, K = 1000, 16, 8, 8
RNG_SEED = 7


def cpu_f32_baseline(a, b, c):
    """'FP32 on CPU': exact products, inner product rounded once to f32,
    then an RNE f32 accumulate — the paper's CPU reference."""
    r = np.einsum("bij,bjk->bik", a.astype(np.float64), b.astype(np.float64))
    s32 = r.astype(np.float32)
    return (s32.astype(np.float64) + c.astype(np.float64)).astype(np.float32)


def profile(cfg, init: str, op: str):
    """Fig. 16 a/b/c input patterns; returns (tc_d00, cpu_d00) arrays."""
    rng = np.random.default_rng(RNG_SEED + hash(op) % 1000)
    a = np.zeros((B, M, K), np.float32)
    b = np.zeros((B, K, N), np.float32)
    c = np.zeros((B, M, N), np.float32)
    maybe_q = (lambda x: ref_quantize(x, init)) if init != "fp32" else (lambda x: x)
    if op == "mul":
        a[:, 0, 0] = maybe_q(rng.standard_normal(B).astype(np.float32))
        b[:, 0, 0] = maybe_q(rng.standard_normal(B).astype(np.float32))
    elif op == "inner":
        a[:, 0, :] = maybe_q(rng.standard_normal((B, K)).astype(np.float32))
        b[:, :, 0] = maybe_q(rng.standard_normal((B, K)).astype(np.float32))
    elif op == "accum":
        a[:, 0, 0] = maybe_q(rng.standard_normal(B).astype(np.float32))
        b[:, 0, 0] = maybe_q(rng.standard_normal(B).astype(np.float32))
        cv = rng.standard_normal(B).astype(np.float32)
        # C/D type is FP32 for *_f32 configs -> no quantization of C;
        # for the fp16_f16 config C itself is FP16.
        c[:, 0, 0] = ref_quantize(cv, "fp16") if cfg.cd == "f16" else cv
    else:
        raise ValueError(op)
    tc = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), cfg))
    cpu = cpu_f32_baseline(a, b, c)
    return tc[:, 0, 0], cpu[:, 0, 0]


def mean_abs_err(cfg, init, op):
    tc, cpu = profile(cfg, init, op)
    return float(np.mean(np.abs(tc - cpu)))


# ------------------------------------------------------------- Table 12


def test_table12_bf16_init_bf16():
    cfg = CONFIGS["bf16_f32"]
    assert mean_abs_err(cfg, "bf16", "mul") == 0.0
    assert mean_abs_err(cfg, "bf16", "inner") == 0.0
    acc = mean_abs_err(cfg, "bf16", "accum")
    assert 1e-9 < acc < 1e-7  # paper: 1.89e-8


def test_table12_bf16_init_fp32():
    cfg = CONFIGS["bf16_f32"]
    for op in ("mul", "inner", "accum"):
        err = mean_abs_err(cfg, "fp32", op)
        assert 1e-4 < err < 1e-2  # paper: ~1.1-1.7e-3


# ------------------------------------------------------------- Table 13


def test_table13_fp16_f32_init_fp16_all_zero():
    cfg = CONFIGS["fp16_f32"]
    for op in ("mul", "inner", "accum"):
        assert mean_abs_err(cfg, "fp16", op) == 0.0


def test_table13_fp16_f32_init_fp32():
    cfg = CONFIGS["fp16_f32"]
    for op in ("mul", "inner", "accum"):
        err = mean_abs_err(cfg, "fp32", op)
        assert 1e-5 < err < 1e-3  # paper: ~1.4-3e-4


# ------------------------------------------------------------- Table 14


def test_table14_fp16_f16_vs_fp32_baseline_nonzero():
    cfg = CONFIGS["fp16_f16"]
    for op in ("mul", "inner", "accum"):
        assert mean_abs_err(cfg, "fp16", op) > 0.0  # D is FP16


def test_table14_fp16_f16_vs_cvt_fp16_baseline_zero():
    """Compared against the CPU FP32 result *converted to FP16*, errors
    vanish under init_FP16: the hardware computes at high precision and
    converts only the final result (the paper's Table 14 finding)."""
    cfg = CONFIGS["fp16_f16"]
    for op in ("mul", "inner", "accum"):
        tc, cpu = profile(cfg, "fp16", op)
        cpu_cvt = cpu.astype(np.float16).astype(np.float32)
        np.testing.assert_array_equal(tc, cpu_cvt)


# ------------------------------------------------------------- Table 15


def test_table15_tf32_init_tf32_all_zero():
    cfg = CONFIGS["tf32_f32"]
    for op in ("mul", "inner", "accum"):
        assert mean_abs_err(cfg, "tf32", op) == 0.0


def test_table15_tf32_same_error_level_as_fp16():
    """TF32 and FP16 have the same 10 mantissa bits -> same error level
    under init_FP32 (paper: Tables 13 vs 15 are near-identical)."""
    e_tf32 = mean_abs_err(CONFIGS["tf32_f32"], "fp32", "mul")
    e_fp16 = mean_abs_err(CONFIGS["fp16_f32"], "fp32", "mul")
    assert 0.5 < e_tf32 / e_fp16 < 2.0


def test_bf16_error_level_higher_than_fp16():
    """BF16 (7 mantissa bits) errs ~8x more than FP16/TF32 (10 bits)."""
    e_bf16 = mean_abs_err(CONFIGS["bf16_f32"], "fp32", "mul")
    e_fp16 = mean_abs_err(CONFIGS["fp16_f32"], "fp32", "mul")
    assert e_bf16 / e_fp16 > 4.0


# ------------------------------------------------------ Fig. 17 (chain)


def chain_errors(cfg, init: str, n_steps: int, trials=64, seed=3):
    """l2 relative error of the chain D=A@B, D->A, vs the FP32 CPU chain."""
    rng = np.random.default_rng(seed)
    m, n, k = 16, 8, 8
    a32 = rng.standard_normal((trials, m, k)).astype(np.float32)
    if init != "fp32":
        a32 = ref_quantize(a32, init)
    a_tc = a32.copy()
    a_cpu = a32.astype(np.float64)
    errs = []
    zero_c = np.zeros((trials, m, n), np.float32)
    for _ in range(n_steps):
        b32 = rng.standard_normal((trials, k, n)).astype(np.float32)
        if init != "fp32":
            b32 = ref_quantize(b32, init)
        d_tc = np.asarray(
            tcmma(jnp.asarray(a_tc), jnp.asarray(b32), jnp.asarray(zero_c), cfg)
        )
        d_cpu = np.einsum("bij,bjk->bik", a_cpu, b32.astype(np.float64)).astype(
            np.float32
        )
        num = np.sqrt(np.sum((d_tc - d_cpu).astype(np.float64) ** 2, axis=(1, 2)))
        den = np.sqrt(np.sum(d_tc.astype(np.float64) ** 2, axis=(1, 2)))
        errs.append(float(np.mean(num / np.maximum(den, 1e-300))))
        a_tc, a_cpu = d_tc, d_cpu.astype(np.float64)
    return errs


def test_fig17_errors_grow_with_chain_length():
    errs = chain_errors(CONFIGS["tf32_f32"], "tf32", 6)
    assert errs[-1] > errs[0]
    assert errs[0] < 1e-5  # "almost zero when chain length is one"


def test_fig17_bf16_worse_than_tf32():
    e_bf16 = chain_errors(CONFIGS["bf16_f32"], "bf16", 5)
    e_tf32 = chain_errors(CONFIGS["tf32_f32"], "tf32", 5)
    assert e_bf16[-1] > 3.0 * e_tf32[-1]


def test_fig17_fp16_overflows_by_n10():
    """FP16 runs into overflow (infinity) around N >= 10 (paper Fig. 17)."""
    cfg = CONFIGS["fp16_f16"]
    rng = np.random.default_rng(4)
    m, n, k = 16, 8, 8
    trials = 32
    a = ref_quantize(rng.standard_normal((trials, m, k)).astype(np.float32), "fp16")
    zero_c = np.zeros((trials, m, n), np.float32)
    overflowed_at = None
    for step in range(1, 15):
        b = ref_quantize(rng.standard_normal((trials, k, n)).astype(np.float32), "fp16")
        a = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(zero_c), cfg))
        if not np.isfinite(a).all():
            overflowed_at = step
            break
    assert overflowed_at is not None and overflowed_at <= 12


def test_fig17_init_fp32_worse_than_init_low():
    e_fp32 = chain_errors(CONFIGS["tf32_f32"], "fp32", 3)
    e_low = chain_errors(CONFIGS["tf32_f32"], "tf32", 3)
    assert e_fp32[0] > 10 * e_low[0]
