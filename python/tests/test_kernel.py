"""Kernel-vs-oracle: the CORE correctness signal for L1.

The Pallas kernel (interpret=True) must match the independent pure-numpy
oracle bit-exactly on every numeric config, including under a hypothesis
sweep of shapes and value ranges."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import CONFIGS, TcMmaConfig, tcmma, tcmma_tile
from compile.kernels.ref import ref_tcmma

ALL_CFGS = sorted(CONFIGS)


def run_both(a, b, c, cfg):
    got = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), cfg))
    want = ref_tcmma(a, b, c, cfg.ab, cfg.cd, cfg.acc_rnd)
    return got, want


@pytest.mark.parametrize("cfg_name", ALL_CFGS)
@pytest.mark.parametrize("shape", [(16, 8, 16), (16, 8, 8), (16, 8, 4), (8, 8, 4)])
def test_kernel_matches_oracle(cfg_name, shape):
    cfg = CONFIGS[cfg_name]
    m, n, k = shape
    rng = np.random.default_rng(hash((cfg_name, shape)) % 2**32)
    a = rng.standard_normal((8, m, k)).astype(np.float32)
    b = rng.standard_normal((8, k, n)).astype(np.float32)
    c = rng.standard_normal((8, m, n)).astype(np.float32)
    got, want = run_both(a, b, c, cfg)
    np.testing.assert_array_equal(got, want)


@given(
    cfg_name=st.sampled_from(ALL_CFGS),
    m=st.sampled_from([1, 4, 8, 16]),
    n=st.sampled_from([1, 4, 8]),
    k=st.sampled_from([1, 2, 4, 8, 16, 32]),
    batch=st.integers(1, 4),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_kernel_matches_oracle_hypothesis(cfg_name, m, n, k, batch, scale, seed):
    cfg = CONFIGS[cfg_name]
    rng = np.random.default_rng(seed)
    a = (rng.standard_normal((batch, m, k)) * scale).astype(np.float32)
    b = (rng.standard_normal((batch, k, n)) * scale).astype(np.float32)
    c = (rng.standard_normal((batch, m, n)) * scale).astype(np.float32)
    got, want = run_both(a, b, c, cfg)
    np.testing.assert_array_equal(got, want)


def test_kernel_zero_inputs():
    cfg = CONFIGS["bf16_f32"]
    z = np.zeros((2, 16, 8), np.float32)
    got, want = run_both(z, np.zeros((2, 8, 8), np.float32), np.zeros((2, 16, 8), np.float32), cfg)
    np.testing.assert_array_equal(got, np.zeros_like(got))
    np.testing.assert_array_equal(got, want)


def test_kernel_identity_times_b_is_quantized_b():
    """A = I (exactly representable) -> D = quantize(B) for f32 C/D."""
    cfg = CONFIGS["tf32_f32"]
    rng = np.random.default_rng(2)
    eye = np.broadcast_to(np.eye(8, dtype=np.float32), (3, 8, 8)).copy()
    b = rng.standard_normal((3, 8, 8)).astype(np.float32)
    c = np.zeros((3, 8, 8), np.float32)
    got = np.asarray(tcmma(jnp.asarray(eye), jnp.asarray(b), jnp.asarray(c), cfg))
    from compile.kernels.ref import ref_quantize

    np.testing.assert_array_equal(got, ref_quantize(b, "tf32"))


def test_fp16_overflow_propagates_to_inf():
    """FP16 C/D saturates to inf — the Fig. 17 chain failure mode."""
    cfg = CONFIGS["fp16_f16"]
    a = np.full((1, 16, 8), 100.0, np.float32)
    b = np.full((1, 8, 8), 100.0, np.float32)
    c = np.zeros((1, 16, 8), np.float32)
    got = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), cfg))
    assert np.isinf(got).all()  # 8 * 1e4 = 8e4 > 65504


def test_fp16_f32_no_overflow_at_same_magnitude():
    cfg = CONFIGS["fp16_f32"]
    a = np.full((1, 16, 8), 100.0, np.float32)
    b = np.full((1, 8, 8), 100.0, np.float32)
    c = np.zeros((1, 16, 8), np.float32)
    got = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), cfg))
    assert np.isfinite(got).all()


def test_bf16_rz_vs_fp16_rne_accumulation_differs():
    """The BF16 path accumulates with RZ: on identical (representable)
    inputs, its |D| can never exceed the exact result, while RNE can."""
    rng = np.random.default_rng(13)
    # values exactly representable in BOTH bf16 and fp16 (7-bit mantissa)
    import ml_dtypes

    a = rng.standard_normal((64, 16, 8)).astype(ml_dtypes.bfloat16).astype(np.float16).astype(np.float32)
    b = rng.standard_normal((64, 8, 8)).astype(ml_dtypes.bfloat16).astype(np.float16).astype(np.float32)
    c = rng.standard_normal((64, 16, 8)).astype(np.float32)
    d_bf = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), CONFIGS["bf16_f32"]))
    d_fp = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), CONFIGS["fp16_f32"]))
    exact = np.einsum("bij,bjk->bik", a.astype(np.float64), b.astype(np.float64))
    s32 = exact.astype(np.float32).astype(np.float64) + c.astype(np.float64)
    assert (np.abs(d_bf.astype(np.float64)) <= np.abs(s32)).all()
    assert not np.array_equal(d_bf, d_fp)  # RZ vs RNE visible


def test_config_validation():
    with pytest.raises(ValueError):
        TcMmaConfig("fp8")
    with pytest.raises(ValueError):
        TcMmaConfig("bf16", "f16")  # fp16-only C/D
    with pytest.raises(ValueError):
        TcMmaConfig("bf16", "f64")


def test_tcmma_shape_validation():
    cfg = CONFIGS["bf16_f32"]
    with pytest.raises(ValueError):
        tcmma(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros((2, 2)), cfg)
    with pytest.raises(ValueError):
        tcmma(
            jnp.zeros((1, 16, 8)), jnp.zeros((1, 4, 8)), jnp.zeros((1, 16, 8)), cfg
        )


def test_tile_matches_batched():
    """tcmma_tile (L2 building block) agrees with the batched Pallas path."""
    cfg = CONFIGS["fp16_f32"]
    rng = np.random.default_rng(21)
    a = rng.standard_normal((1, 16, 16)).astype(np.float32)
    b = rng.standard_normal((1, 16, 8)).astype(np.float32)
    c = rng.standard_normal((1, 16, 8)).astype(np.float32)
    batched = np.asarray(tcmma(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), cfg))
    tile = np.asarray(tcmma_tile(jnp.asarray(a[0]), jnp.asarray(b[0]), jnp.asarray(c[0]), cfg))
    np.testing.assert_array_equal(batched[0], tile)
