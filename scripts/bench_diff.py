#!/usr/bin/env python3
"""Per-plan wall-time regression gate over bench_summary.json.

Usage:
    python3 scripts/bench_diff.py BASELINE NEW [--threshold PCT]
                                  [--min-share PCT] [--absolute]
                                  [--allow-new-plans] [--summary-md PATH]
                                  [--profile-summary PATH]

Compares each plan's wall time between a committed baseline
(`bench_baseline.json`, produced by `repro all --out DIR`) and a fresh
run. Each plan's growth ratio (new/base) is normalized by the campaign's
*median* growth ratio, so a uniform machine-speed difference between the
baseline runner and this runner cancels out while a single regressed
plan stands out whatever its weight (pass --absolute to compare raw
wall_ms instead). A plan fails the gate when its normalized time grows
by more than --threshold percent (default 25). Plans below --min-share
percent of the baseline campaign (default 0.5) are reported but never
fail: their wall times are noise-dominated.

Plan rows must match one-to-one: a plan present in only one of the two
files fails the gate with a per-plan message naming it (a baseline-only
row means the campaign silently lost coverage; a new-only row means the
baseline is stale and must be refreshed to start gating it). Pass
--allow-new-plans to downgrade new-only rows to notices while a PR that
*adds* plans is in flight.

A baseline with `"bootstrap": true` or an empty plan list passes with a
notice — refresh it with the one-liner:

    target/release/repro all --backend native --out out && cp out/bench_summary.json bench_baseline.json

--summary-md PATH additionally writes a per-plan baseline-vs-current
markdown table (one row per plan, flagged like the stdout report) meant
to be appended to a CI job summary ($GITHUB_STEP_SUMMARY). The file is
written on success AND on regression, so the CI step can publish it
before propagating the exit code.

When a profile_summary.json (written by `repro all --out DIR` next to
bench_summary.json) is readable — by default looked up alongside NEW,
or at an explicit --profile-summary PATH — the markdown table gains a
"top stalls" column showing each plan's dominant stall-attribution
categories. A missing or unreadable profile summary never fails the
gate; the column is simply omitted.

Exit codes: 0 = ok (or bootstrap baseline), 1 = regression, 2 = bad input.
"""

import argparse
import json
import os
import statistics
import sys

REFRESH = (
    "target/release/repro all --backend native --out out "
    "&& cp out/bench_summary.json bench_baseline.json"
)


def write_summary_md(path, lines):
    # The summary is auxiliary output: a write failure must not mask the
    # gate's real verdict (exit 0/1), so warn instead of exiting.
    try:
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
    except OSError as e:
        print(f"bench_diff: warning: cannot write summary {path}: {e}",
              file=sys.stderr)


def load_plans(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    schema = doc.get("schema", "")
    if not schema.startswith("tcbench/bench_summary/"):
        print(f"bench_diff: {path} has unexpected schema {schema!r}", file=sys.stderr)
        sys.exit(2)
    plans = {}
    for p in doc.get("plans", []):
        pid, wall = p.get("id"), p.get("wall_ms")
        if isinstance(pid, str) and isinstance(wall, (int, float)) and wall >= 0:
            plans[pid] = float(wall)
    return doc, plans


def load_profiles(path):
    """Plan id -> {category: fraction} from a profile_summary.json.

    Auxiliary data for the markdown summary only: any read/shape problem
    returns None (no column) instead of failing the gate.
    """
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    if not str(doc.get("schema", "")).startswith("tcbench/profile_summary/"):
        return None
    profiles = {}
    for row in doc.get("plans", []):
        pid = row.get("id")
        profile = row.get("profile")
        fractions = profile.get("fractions") if isinstance(profile, dict) else None
        if isinstance(pid, str) and isinstance(fractions, dict):
            profiles[pid] = {k: float(v) for k, v in fractions.items()
                             if isinstance(v, (int, float))}
    return profiles or None


def stall_cell(fractions, top=3):
    """The dominant stall categories of one plan, as a compact cell."""
    if not fractions:
        return "—"
    ranked = sorted(((v, k) for k, v in fractions.items() if v > 0), reverse=True)
    if not ranked:
        return "—"
    return " · ".join(f"{k} {v * 100.0:.0f}%" for v, k in ranked[:top])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=25.0,
                    help="max allowed per-plan growth beyond the campaign's "
                         "median drift, percent (default 25)")
    ap.add_argument("--min-share", type=float, default=0.5,
                    help="plans below this share of the baseline campaign "
                         "(percent) never fail the gate (default 0.5)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw wall_ms (no median-drift normalization)")
    ap.add_argument("--allow-new-plans", action="store_true",
                    help="report plans missing from the baseline as notices "
                         "instead of failures (for PRs that add plans)")
    ap.add_argument("--summary-md", metavar="PATH",
                    help="also write a per-plan baseline-vs-current markdown "
                         "table to PATH (for $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--profile-summary", metavar="PATH",
                    help="profile_summary.json with per-plan stall attribution "
                         "(default: looked up alongside NEW); adds a 'top "
                         "stalls' column to --summary-md when readable")
    args = ap.parse_args(argv)

    profile_path = args.profile_summary or os.path.join(
        os.path.dirname(args.new) or ".", "profile_summary.json")
    profiles = load_profiles(profile_path)

    base_doc, base = load_plans(args.baseline)
    _, new = load_plans(args.new)

    if base_doc.get("bootstrap") or not base:
        print(f"bench_diff: baseline {args.baseline} is a bootstrap placeholder — "
              f"nothing to gate on.\nRefresh it with:\n    {REFRESH}")
        if args.summary_md:
            write_summary_md(args.summary_md, [
                "### Bench diff",
                "",
                f"Baseline `{args.baseline}` is a **bootstrap placeholder** — "
                f"nothing to gate on. Refresh it with:",
                "",
                f"    {REFRESH}",
            ])
        return 0

    base_total = sum(base.values()) or 1.0
    common = [pid for pid in base if pid in new and base[pid] > 0]
    ratios = {pid: new[pid] / base[pid] for pid in common}
    eligible = [pid for pid in common
                if base[pid] / base_total * 100.0 >= args.min_share]
    if args.absolute or not eligible:
        scale = 1.0
    else:
        scale = statistics.median(ratios[pid] for pid in eligible) or 1.0

    regressions, notes, md_rows = [], [], []
    print(f"bench_diff: {len(base)} baseline plans vs {len(new)} new "
          f"(median drift x{scale:.2f}, threshold +{args.threshold:.0f}%)")
    print(f"{'plan':<16} {'base ms':>10} {'new ms':>10} {'vs median':>10}")
    for pid in sorted(base):
        if pid not in new:
            regressions.append(
                f"{pid}: present in the baseline but missing from the new run — "
                f"the campaign lost this plan (removed or renamed?); if "
                f"intentional, refresh the baseline")
            print(f"{pid:<16} {base[pid]:>10.1f} {'MISSING':>10}   MISSING-IN-NEW")
            md_rows.append((pid, f"{base[pid]:.1f}", "—", "—", "❌ missing in new run"))
            continue
        if base[pid] <= 0:
            # not gateable (no growth ratio), but the summary table keeps
            # its one-row-per-plan contract
            md_rows.append((pid, f"{base[pid]:.1f}", f"{new[pid]:.1f}", "—",
                            "skipped (zero-ms baseline)"))
            continue
        pct = (ratios[pid] / scale - 1.0) * 100.0
        flag = ""
        status = "ok"
        if pct > args.threshold:
            if pid not in eligible:
                flag = f"  (ignored: <{args.min_share:.1f}% of campaign)"
                status = f"ignored (<{args.min_share:.1f}% of campaign)"
            else:
                flag = "  REGRESSION"
                status = "❌ REGRESSION"
                regressions.append(f"{pid}: +{pct:.1f}% beyond the campaign's median drift")
        print(f"{pid:<16} {base[pid]:>10.1f} {new[pid]:>10.1f} {pct:>+9.1f}%{flag}")
        md_rows.append((pid, f"{base[pid]:.1f}", f"{new[pid]:.1f}", f"{pct:+.1f}%", status))
    for pid in sorted(set(new) - set(base)):
        msg = (f"{pid}: present in the new run but missing from the baseline — "
               f"refresh the baseline to start gating it")
        if args.allow_new_plans:
            notes.append(msg)
            md_rows.append((pid, "—", f"{new[pid]:.1f}", "—", "new plan (not gated)"))
        else:
            regressions.append(msg)
            print(f"{pid:<16} {'MISSING':>10} {new[pid]:>10.1f}   MISSING-IN-BASELINE")
            md_rows.append((pid, "—", f"{new[pid]:.1f}", "—", "❌ missing in baseline"))

    for note in notes:
        print(f"note: {note}")
    if args.summary_md:
        verdict = (f"**{len(regressions)} failure(s)**" if regressions
                   else "no per-plan regressions beyond the threshold")
        md = [
            "### Bench diff: baseline vs current",
            "",
            f"Median drift ×{scale:.2f}, threshold +{args.threshold:.0f}% — {verdict}.",
            "",
        ]
        if profiles:
            md += [
                "| plan | base ms | new ms | vs median | top stalls | status |",
                "|---|---:|---:|---:|---|---|",
            ]
            md.extend(
                f"| {pid} | {b} | {n} | {pct} | {stall_cell(profiles.get(pid))} "
                f"| {status} |"
                for pid, b, n, pct, status in md_rows)
        else:
            md += [
                "| plan | base ms | new ms | vs median | status |",
                "|---|---:|---:|---:|---|",
            ]
            md.extend(f"| {pid} | {b} | {n} | {pct} | {status} |"
                      for pid, b, n, pct, status in md_rows)
        write_summary_md(args.summary_md, md)
    if regressions:
        print(f"\nbench_diff: {len(regressions)} failure(s) "
              f"(threshold +{args.threshold:.0f}%):", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        print(f"\nIf intentional, refresh the baseline:\n    {REFRESH}", file=sys.stderr)
        return 1
    print("bench_diff: no per-plan regressions beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
