"""Tests for scripts/bench_diff.py — the per-plan wall-time gate.

Runs with the standard library only:

    python3 -m unittest discover -s scripts/tests -v

(pytest collects these too, via unittest integration).
"""

import contextlib
import importlib.util
import io
import json
import os
import tempfile
import unittest

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), "..", "bench_diff.py"),
)
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def summary(plans, **extra):
    doc = {"schema": "tcbench/bench_summary/v1", "plans": [
        {"id": pid, "wall_ms": ms} for pid, ms in plans.items()
    ]}
    doc.update(extra)
    return doc


def profile_summary(plans):
    return {"schema": "tcbench/profile_summary/v1", "plans": [
        {"id": pid, "profile": {"fractions": fractions}}
        for pid, fractions in plans.items()
    ]}


class BenchDiffTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def write(self, name, doc):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def run_diff(self, base_doc, new_doc, *flags):
        base = self.write("base.json", base_doc)
        new = self.write("new.json", new_doc)
        out, err = io.StringIO(), io.StringIO()
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            try:
                rc = bench_diff.main([base, new, *flags])
            except SystemExit as e:  # load_plans exits directly on bad input
                rc = e.code
        return rc, out.getvalue(), err.getvalue()

    def test_identical_runs_pass(self):
        doc = summary({"t3": 100.0, "t12": 50.0, "gemm_pipeline": 200.0})
        rc, out, _ = self.run_diff(doc, doc)
        self.assertEqual(rc, 0)
        self.assertIn("no per-plan regressions", out)

    def test_uniform_machine_drift_cancels(self):
        base = summary({"t3": 100.0, "t12": 50.0, "fig17": 200.0})
        new = summary({"t3": 300.0, "t12": 150.0, "fig17": 600.0})  # 3x everywhere
        rc, _, _ = self.run_diff(base, new)
        self.assertEqual(rc, 0)

    def test_single_plan_regression_fails(self):
        base = summary({"t3": 100.0, "t12": 100.0, "fig17": 100.0})
        new = summary({"t3": 100.0, "t12": 100.0, "fig17": 200.0})
        rc, _, err = self.run_diff(base, new)
        self.assertEqual(rc, 1)
        self.assertIn("fig17", err)
        self.assertIn("median drift", err)

    def test_row_only_in_baseline_fails_with_named_plan(self):
        base = summary({"t3": 100.0, "numeric_chain_tf32": 40.0})
        new = summary({"t3": 100.0})
        rc, out, err = self.run_diff(base, new)
        self.assertEqual(rc, 1)
        self.assertIn("numeric_chain_tf32", err)
        self.assertIn("missing from the new run", err)
        self.assertIn("MISSING-IN-NEW", out)

    def test_row_only_in_new_run_fails_with_named_plan(self):
        base = summary({"t3": 100.0})
        new = summary({"t3": 100.0, "numeric_profile_bf16": 12.0})
        rc, out, err = self.run_diff(base, new)
        self.assertEqual(rc, 1)
        self.assertIn("numeric_profile_bf16", err)
        self.assertIn("missing from the baseline", err)
        self.assertIn("refresh the baseline", err)
        self.assertIn("MISSING-IN-BASELINE", out)

    def test_allow_new_plans_downgrades_to_notice(self):
        base = summary({"t3": 100.0})
        new = summary({"t3": 100.0, "numeric_profile_bf16": 12.0})
        rc, out, _ = self.run_diff(base, new, "--allow-new-plans")
        self.assertEqual(rc, 0)
        self.assertIn("note: numeric_profile_bf16", out)

    def test_bootstrap_baseline_passes_with_notice(self):
        base = summary({}, bootstrap=True)
        new = summary({"t3": 100.0, "numeric_chain_tf32": 40.0})
        rc, out, _ = self.run_diff(base, new)
        self.assertEqual(rc, 0)
        self.assertIn("bootstrap", out)

    def test_tiny_plans_never_fail(self):
        # a plan under --min-share of the campaign is noise-dominated
        base = summary({"t3": 1000.0, "tiny": 1.0})
        new = summary({"t3": 1000.0, "tiny": 10.0})
        rc, out, _ = self.run_diff(base, new)
        self.assertEqual(rc, 0)
        self.assertIn("ignored", out)

    def test_bad_schema_is_exit_2(self):
        base = self.write("base.json", {"schema": "something/else", "plans": []})
        new = self.write("new.json", summary({"t3": 1.0}))
        out, err = io.StringIO(), io.StringIO()
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            with self.assertRaises(SystemExit) as ctx:
                bench_diff.main([base, new])
        self.assertEqual(ctx.exception.code, 2)
        self.assertIn("unexpected schema", err.getvalue())

    def summary_md(self):
        return os.path.join(self.dir.name, "summary.md")

    def read_md(self):
        with open(self.summary_md()) as f:
            return f.read()

    def test_summary_md_writes_per_plan_table(self):
        base = summary({"t3": 100.0, "fig6": 200.0})
        new = summary({"t3": 110.0, "fig6": 210.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        md = self.read_md()
        self.assertIn("| plan | base ms | new ms | vs median | status |", md)
        self.assertIn("| t3 | 100.0 | 110.0 |", md)
        self.assertIn("| fig6 | 200.0 | 210.0 |", md)
        self.assertIn("no per-plan regressions", md)

    def test_summary_md_flags_regressions_and_still_fails(self):
        base = summary({"t3": 100.0, "t12": 100.0, "fig17": 100.0})
        new = summary({"t3": 100.0, "t12": 100.0, "fig17": 200.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 1)  # the file is written AND the gate fails
        md = self.read_md()
        self.assertIn("REGRESSION", md)
        self.assertIn("1 failure(s)", md)
        self.assertIn("| fig17 | 100.0 | 200.0 |", md)

    def test_summary_md_marks_rows_missing_on_either_side(self):
        base = summary({"t3": 100.0, "gone": 50.0})
        new = summary({"t3": 100.0, "fresh": 25.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 1)
        md = self.read_md()
        self.assertIn("missing in new run", md)
        self.assertIn("missing in baseline", md)

    def test_summary_md_keeps_zero_ms_baseline_rows(self):
        # a zero-ms baseline row cannot be gated, but it must not vanish
        # from the per-plan table
        base = summary({"t3": 100.0, "zero": 0.0})
        new = summary({"t3": 100.0, "zero": 5.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        md = self.read_md()
        self.assertIn("| zero | 0.0 | 5.0 |", md)
        self.assertIn("zero-ms baseline", md)

    def test_summary_md_bootstrap_baseline_writes_notice(self):
        base = summary({}, bootstrap=True)
        new = summary({"t3": 100.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        self.assertIn("bootstrap placeholder", self.read_md())

    def test_summary_md_gains_stall_column_from_profile_summary(self):
        # profile_summary.json sits next to new.json, so the default
        # lookup finds it without any extra flag
        self.write("profile_summary.json", profile_summary({
            "t3": {"issued": 0.45, "scoreboard_dep": 0.30, "token_bucket": 0.15,
                   "issue_slot": 0.10, "smem_conflict": 0.0},
        }))
        base = summary({"t3": 100.0, "fig6": 200.0})
        new = summary({"t3": 100.0, "fig6": 200.0})
        rc, _, _ = self.run_diff(base, new, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        md = self.read_md()
        self.assertIn("| top stalls |", md)
        # top-3 categories, largest first; zero categories never listed
        self.assertIn("issued 45% · scoreboard_dep 30% · token_bucket 15%", md)
        self.assertNotIn("smem_conflict", md)
        # a plan with no profile row keeps a placeholder cell
        self.assertIn("| fig6 | 200.0 | 200.0 | +0.0% | — | ok |", md)

    def test_summary_md_without_profile_summary_keeps_old_table(self):
        base = summary({"t3": 100.0})
        rc, _, _ = self.run_diff(base, base, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        md = self.read_md()
        self.assertNotIn("top stalls", md)
        self.assertIn("| plan | base ms | new ms | vs median | status |", md)

    def test_unreadable_profile_summary_is_ignored_not_fatal(self):
        # wrong schema -> no column, and the gate's verdict is untouched
        self.write("profile_summary.json", {"schema": "something/else"})
        base = summary({"t3": 100.0})
        rc, _, _ = self.run_diff(base, base, "--summary-md", self.summary_md())
        self.assertEqual(rc, 0)
        self.assertNotIn("top stalls", self.read_md())

    def test_explicit_profile_summary_path_wins(self):
        path = self.write("elsewhere.json", profile_summary({
            "t3": {"issued": 1.0},
        }))
        base = summary({"t3": 100.0})
        rc, _, _ = self.run_diff(base, base, "--summary-md", self.summary_md(),
                                 "--profile-summary", path)
        self.assertEqual(rc, 0)
        self.assertIn("issued 100%", self.read_md())

    def test_absolute_mode_skips_normalization(self):
        base = summary({"t3": 100.0, "t12": 100.0, "fig17": 100.0})
        new = summary({"t3": 150.0, "t12": 150.0, "fig17": 150.0})  # uniform +50%
        rc, _, _ = self.run_diff(base, new)
        self.assertEqual(rc, 0)  # normalized: cancels
        rc, _, err = self.run_diff(base, new, "--absolute")
        self.assertEqual(rc, 1)  # absolute: every plan +50%
        self.assertIn("t3", err)


if __name__ == "__main__":
    unittest.main()
